//! Shared work-stealing scheduler: thousands of streams per core.
//!
//! SamBaTen's pitch is scale — summarize and compute in a reduced space so
//! one box keeps up with ever-growing tensors — but a serving layer that
//! spends one OS thread per registered stream caps the "millions of users"
//! story at a few hundred mostly-idle streams. This module is the layer
//! between the service and the OS: a **fixed-size worker pool** with
//! **keyed FIFO ordering**.
//!
//! * [`WorkPool`] — `N` worker threads (sized to the hardware by default),
//!   a global injector queue, one local run queue per worker, and
//!   work-stealing between them. Idle workers park on a condvar and are
//!   unparked exactly when work arrives.
//! * [`KeyHandle`] — an ordering key (one per stream). Tasks under one key
//!   run sequentially in submission order and never concurrently; the key
//!   itself circulates through the run queues, so independent keys steal
//!   freely across workers (see `mailbox.rs` for the mechanism and the
//!   bounded-mailbox backpressure contract).
//! * [`WorkPool::fanout`] / [`WorkPool::parallel_map`] — scoped, unkeyed
//!   fan-out for intra-task parallelism (the engine's per-repetition
//!   sample-ALS). The caller participates in draining its own fan-out, so
//!   a fan-out issued *from a pool worker* always makes progress even when
//!   every other worker is busy — no thread-starvation deadlock by
//!   construction (see `fanout.rs`).
//! * **Panic isolation** — every task runs under `catch_unwind`: a
//!   poisoned task fails its own ticket (and is counted in the key's and
//!   pool's stats) while the worker thread, the key, and every other
//!   stream keep running.
//! * [`WorkPool::shutdown`] — graceful: new submissions are rejected,
//!   everything already accepted drains, then the workers are joined.
//!
//! Scheduling protocol in one paragraph: a submission lands in its key's
//! bounded mailbox; if the key was unscheduled it is marked scheduled and
//! pushed to a run queue (the submitter's local queue when submitting from
//! a worker, the global injector otherwise). Workers pop their own queue
//! first, then the injector, then steal from siblings. A worker holding a
//! key drains up to a small quantum of its mailbox (amortising the queue
//! hops) and then either unschedules the key (mailbox empty) or re-queues
//! it locally (fairness across keys). `benches/bench_micro.rs` measures
//! the headline: 1 000 registered streams served by 8 workers at ≥ the
//! ingest throughput of 1 000 dedicated threads.

mod fanout;
mod mailbox;

pub use fanout::ScopedTask;
pub use mailbox::{KeyHandle, KeyStats};

use crate::util::par::hardware_parallelism;
use mailbox::KeyState;
use std::cell::Cell;
use std::collections::VecDeque;
use std::panic::AssertUnwindSafe;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::thread::JoinHandle;

/// A unit of work owned by the pool ('static — scoped work goes through
/// [`WorkPool::fanout`], which erases the lifetime behind a completion
/// barrier).
pub(crate) type Task = Box<dyn FnOnce() + Send + 'static>;

/// What circulates through the run queues: an ordering key (whose mailbox
/// holds its tasks) or a free-standing unkeyed task (fan-out helpers).
pub(crate) enum Runnable {
    Key(Arc<KeyState>),
    Task(Task),
}

/// How many tasks of one key a worker drains before re-queueing the key —
/// amortises queue traffic without letting one hot key monopolise a worker.
const KEY_QUANTUM: usize = 8;

thread_local! {
    /// `(pool address, worker index)` of the pool worker running on this
    /// thread, if any — lets keyed submissions issued *from* a worker
    /// schedule onto that worker's local queue instead of the shared
    /// injector (fan-out helper stubs deliberately always go through the
    /// injector, where idle workers find them fastest), and lets
    /// `WorkPool::drop` detect the dropped-from-own-worker case.
    static WORKER_CTX: Cell<Option<(usize, usize)>> = const { Cell::new(None) };

    /// Addresses of the keys whose `run_key` frames are on this thread's
    /// stack (nested via `help_drain_one`). A task submitting to a key
    /// *held by its own thread* must not wait for a mailbox slot — only
    /// this thread could free it — so such self-sends bypass the bound
    /// (see `KeyHandle::submit`).
    static HELD_KEYS: std::cell::RefCell<Vec<usize>> =
        const { std::cell::RefCell::new(Vec::new()) };
}

/// Is `key` currently being run by this very thread (at any nesting depth)?
pub(crate) fn key_held_by_this_thread(key: &Arc<KeyState>) -> bool {
    let addr = Arc::as_ptr(key) as usize;
    HELD_KEYS.with(|h| h.borrow().contains(&addr))
}

/// Pops the top of [`HELD_KEYS`] on drop — unwind-safe bookkeeping for
/// `run_key`'s multiple exits.
struct HeldKeyGuard;

impl Drop for HeldKeyGuard {
    fn drop(&mut self) {
        HELD_KEYS.with(|h| {
            h.borrow_mut().pop();
        });
    }
}

pub(crate) struct PoolInner {
    injector: Mutex<VecDeque<Runnable>>,
    locals: Vec<Mutex<VecDeque<Runnable>>>,
    /// Runnables sitting in any run queue.
    pending: AtomicUsize,
    /// Runnables currently being executed by a worker.
    active: AtomicUsize,
    /// Submissions past their closed-check but not yet enqueued — shutdown
    /// drains only after this reaches zero (see `KeyHandle::submit`).
    submitting: AtomicUsize,
    /// No new work accepted; queued work still drains.
    pub(crate) closed: AtomicBool,
    /// Workers exit once the queues are empty.
    terminate: AtomicBool,
    sleep: Mutex<()>,
    wake: Condvar,
    sleepers: AtomicUsize,
    // Lifetime counters (Relaxed: diagnostics, not synchronisation).
    keys_registered: AtomicU64,
    tasks_executed: AtomicU64,
    steals: AtomicU64,
    injected: AtomicU64,
    panics: AtomicU64,
}

impl PoolInner {
    fn lock_queue<'a>(
        &self,
        q: &'a Mutex<VecDeque<Runnable>>,
    ) -> MutexGuard<'a, VecDeque<Runnable>> {
        // Queue critical sections are push/pop only — recover poisoning.
        q.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Worker index on this thread if it is one of *this* pool's workers.
    pub(crate) fn current_local(&self) -> Option<usize> {
        let me = self as *const PoolInner as usize;
        WORKER_CTX.with(|w| match w.get() {
            Some((pool, idx)) if pool == me => Some(idx),
            _ => None,
        })
    }

    /// Enqueue a runnable (to worker `local`'s queue, or the injector) and
    /// wake a parked worker. Infallible by design: everything *accepted*
    /// (a scheduled key, a fan-out helper) must reach a queue — admission
    /// control happens before this point.
    pub(crate) fn push_runnable(&self, r: Runnable, local: Option<usize>) {
        self.pending.fetch_add(1, Ordering::SeqCst);
        match local {
            Some(i) => self.lock_queue(&self.locals[i]).push_back(r),
            None => {
                self.lock_queue(&self.injector).push_back(r);
                self.injected.fetch_add(1, Ordering::Relaxed);
            }
        }
        self.wake_one();
    }

    /// Inject an unkeyed task unless the pool is shutting down. Fan-out
    /// helpers use this: losing one is harmless (the fan-out caller drains
    /// its own queue), so no in-flight guard is needed.
    pub(crate) fn try_inject_task(&self, t: Task) -> bool {
        if self.closed.load(Ordering::SeqCst) {
            return false;
        }
        self.push_runnable(Runnable::Task(t), None);
        true
    }

    pub(crate) fn enter_submit(&self) -> SubmitGuard<'_> {
        self.submitting.fetch_add(1, Ordering::SeqCst);
        SubmitGuard { pool: self }
    }

    /// Run one queued runnable on this worker thread, or yield if none —
    /// the anti-deadlock escape for worker-context submitters blocked on a
    /// full mailbox (see `KeyHandle::submit`). Keyed exclusivity is
    /// preserved: `run_key` is entered only by whoever popped the key.
    pub(crate) fn help_drain_one(&self, idx: usize) {
        match self.next_runnable(idx) {
            Some(r) => self.run(r, Some(idx)),
            None => std::thread::yield_now(),
        }
    }

    /// Pop the next runnable for worker `idx`: own queue, then the
    /// injector, then steal from siblings.
    fn next_runnable(&self, idx: usize) -> Option<Runnable> {
        if let Some(r) = self.lock_queue(&self.locals[idx]).pop_front() {
            self.pending.fetch_sub(1, Ordering::SeqCst);
            return Some(r);
        }
        if let Some(r) = self.lock_queue(&self.injector).pop_front() {
            self.pending.fetch_sub(1, Ordering::SeqCst);
            return Some(r);
        }
        let n = self.locals.len();
        for off in 1..n {
            let victim = (idx + off) % n;
            if let Some(r) = self.lock_queue(&self.locals[victim]).pop_front() {
                self.pending.fetch_sub(1, Ordering::SeqCst);
                self.steals.fetch_add(1, Ordering::Relaxed);
                return Some(r);
            }
        }
        None
    }

    /// Pop from anywhere — the post-join defensive sweep in `shutdown`.
    fn pop_any(&self) -> Option<Runnable> {
        if let Some(r) = self.lock_queue(&self.injector).pop_front() {
            self.pending.fetch_sub(1, Ordering::SeqCst);
            return Some(r);
        }
        for q in &self.locals {
            if let Some(r) = self.lock_queue(q).pop_front() {
                self.pending.fetch_sub(1, Ordering::SeqCst);
                return Some(r);
            }
        }
        None
    }

    fn run(&self, r: Runnable, local: Option<usize>) {
        self.active.fetch_add(1, Ordering::SeqCst);
        match r {
            Runnable::Task(t) => {
                if std::panic::catch_unwind(AssertUnwindSafe(t)).is_err() {
                    self.panics.fetch_add(1, Ordering::Relaxed);
                }
                self.tasks_executed.fetch_add(1, Ordering::Relaxed);
            }
            Runnable::Key(k) => self.run_key(k, local),
        }
        self.active.fetch_sub(1, Ordering::SeqCst);
    }

    /// Drain up to [`KEY_QUANTUM`] tasks of one key, then unschedule it
    /// (mailbox empty) or re-queue it. Every `scheduled` transition happens
    /// under the mailbox lock, which is what makes the ordering invariant
    /// airtight: a concurrent submit either sees `scheduled == true` (the
    /// task will be found by the check below or a later activation) or
    /// re-schedules the key itself.
    fn run_key(&self, key: Arc<KeyState>, local: Option<usize>) {
        HELD_KEYS.with(|h| h.borrow_mut().push(Arc::as_ptr(&key) as usize));
        let _held = HeldKeyGuard;
        let mut ran = 0usize;
        loop {
            let task = {
                let mut mb = key.mailbox_lock();
                match mb.queue.pop_front() {
                    Some(t) => t,
                    None => {
                        mb.scheduled = false;
                        drop(mb);
                        key.idle.notify_all();
                        return;
                    }
                }
            };
            // A slot freed: wake one submitter blocked on backpressure.
            key.not_full.notify_one();
            if std::panic::catch_unwind(AssertUnwindSafe(task)).is_err() {
                // Panic isolation: the task poisoned itself (its ticket
                // observes the failure through its own channel); the key
                // and the worker keep going.
                key.panicked.fetch_add(1, Ordering::Relaxed);
                self.panics.fetch_add(1, Ordering::Relaxed);
            }
            key.completed.fetch_add(1, Ordering::Relaxed);
            self.tasks_executed.fetch_add(1, Ordering::Relaxed);
            ran += 1;
            if ran >= KEY_QUANTUM {
                let reschedule = {
                    let mut mb = key.mailbox_lock();
                    if mb.queue.is_empty() {
                        mb.scheduled = false;
                        false
                    } else {
                        true
                    }
                };
                if reschedule {
                    self.push_runnable(Runnable::Key(key), local);
                } else {
                    key.idle.notify_all();
                }
                return;
            }
        }
    }

    /// Park until woken. The sleeper count is incremented *before* the
    /// pending re-check and both sides use SeqCst, so a pusher either sees
    /// the sleeper (and notifies under the lock) or the parker sees the
    /// pushed work — no lost wakeup.
    fn park(&self) {
        let guard = self.sleep.lock().unwrap_or_else(|e| e.into_inner());
        self.sleepers.fetch_add(1, Ordering::SeqCst);
        if self.pending.load(Ordering::SeqCst) == 0 && !self.terminate.load(Ordering::SeqCst) {
            drop(self.wake.wait(guard).unwrap_or_else(|e| e.into_inner()));
        } else {
            drop(guard);
        }
        self.sleepers.fetch_sub(1, Ordering::SeqCst);
    }

    fn wake_one(&self) {
        if self.sleepers.load(Ordering::SeqCst) > 0 {
            let _guard = self.sleep.lock().unwrap_or_else(|e| e.into_inner());
            self.wake.notify_one();
        }
    }

    fn wake_all(&self) {
        let _guard = self.sleep.lock().unwrap_or_else(|e| e.into_inner());
        self.wake.notify_all();
    }
}

/// RAII guard for an in-flight submission (see `PoolInner::submitting`).
pub(crate) struct SubmitGuard<'a> {
    pool: &'a PoolInner,
}

impl Drop for SubmitGuard<'_> {
    fn drop(&mut self) {
        self.pool.submitting.fetch_sub(1, Ordering::SeqCst);
    }
}

fn worker_loop(inner: Arc<PoolInner>, idx: usize) {
    let me = Arc::as_ptr(&inner) as usize;
    WORKER_CTX.with(|w| w.set(Some((me, idx))));
    loop {
        match inner.next_runnable(idx) {
            Some(r) => inner.run(r, Some(idx)),
            None => {
                if inner.terminate.load(Ordering::SeqCst) {
                    break;
                }
                inner.park();
            }
        }
    }
    WORKER_CTX.with(|w| w.set(None));
}

/// Aggregate point-in-time pool statistics.
#[derive(Clone, Debug)]
pub struct PoolStats {
    /// Worker thread count (fixed at construction).
    pub workers: usize,
    /// Keys registered over the pool's lifetime.
    pub keys_registered: u64,
    /// Runnables currently waiting in run queues (keys + fan-out helpers).
    pub queued: usize,
    /// Runnables currently executing.
    pub active: usize,
    /// Tasks executed to completion (keyed and unkeyed, panicked included).
    pub tasks_executed: u64,
    /// Runnables taken from a sibling worker's queue.
    pub steals: u64,
    /// Runnables pushed through the global injector.
    pub injected: u64,
    /// Tasks that panicked (isolated; the pool survived every one).
    pub panics: u64,
}

/// A fixed-size work-stealing worker pool with keyed FIFO ordering. See
/// the module docs for the scheduling protocol and guarantees.
pub struct WorkPool {
    inner: Arc<PoolInner>,
    workers: Mutex<Vec<JoinHandle<()>>>,
    nworkers: usize,
}

impl WorkPool {
    /// Spawn a pool of `workers` threads; `0` sizes it to the hardware.
    pub fn new(workers: usize) -> WorkPool {
        let nworkers = if workers == 0 { hardware_parallelism() } else { workers };
        let inner = Arc::new(PoolInner {
            injector: Mutex::new(VecDeque::new()),
            locals: (0..nworkers).map(|_| Mutex::new(VecDeque::new())).collect(),
            pending: AtomicUsize::new(0),
            active: AtomicUsize::new(0),
            submitting: AtomicUsize::new(0),
            closed: AtomicBool::new(false),
            terminate: AtomicBool::new(false),
            sleep: Mutex::new(()),
            wake: Condvar::new(),
            sleepers: AtomicUsize::new(0),
            keys_registered: AtomicU64::new(0),
            tasks_executed: AtomicU64::new(0),
            steals: AtomicU64::new(0),
            injected: AtomicU64::new(0),
            panics: AtomicU64::new(0),
        });
        let handles = (0..nworkers)
            .map(|idx| {
                let inner = inner.clone();
                std::thread::Builder::new()
                    .name(format!("sambaten-pool-{idx}"))
                    .spawn(move || worker_loop(inner, idx))
                    .expect("spawning pool worker")
            })
            .collect();
        WorkPool { inner, workers: Mutex::new(handles), nworkers }
    }

    /// Worker thread count.
    pub fn workers(&self) -> usize {
        self.nworkers
    }

    /// Register a new ordering key (one per stream). Tasks submitted via
    /// the returned handle run sequentially in submission order; `cap`
    /// bounds the key's mailbox (min 1 — a full mailbox blocks the
    /// submitter).
    pub fn register_key(&self, label: &str, cap: usize) -> anyhow::Result<KeyHandle> {
        anyhow::ensure!(
            !self.inner.closed.load(Ordering::SeqCst),
            "worker pool is shutting down"
        );
        self.inner.keys_registered.fetch_add(1, Ordering::Relaxed);
        Ok(KeyHandle { key: Arc::new(KeyState::new(label, cap)), pool: self.inner.clone() })
    }

    pub fn stats(&self) -> PoolStats {
        let i = &self.inner;
        PoolStats {
            workers: self.nworkers,
            keys_registered: i.keys_registered.load(Ordering::Relaxed),
            queued: i.pending.load(Ordering::SeqCst),
            active: i.active.load(Ordering::SeqCst),
            tasks_executed: i.tasks_executed.load(Ordering::Relaxed),
            steals: i.steals.load(Ordering::Relaxed),
            injected: i.injected.load(Ordering::Relaxed),
            panics: i.panics.load(Ordering::Relaxed),
        }
    }

    /// Graceful shutdown: reject new submissions, let everything already
    /// accepted drain (tickets resolve), join the workers. Idempotent.
    /// Must not be called from a pool task (the drain would wait on the
    /// calling task itself).
    pub fn shutdown(&self) {
        self.inner.closed.store(true, Ordering::SeqCst);
        // Drain: wait until no submission is mid-flight, no runnable is
        // queued and none is executing. Polling keeps this wait-free for
        // the workers (no extra bookkeeping on the per-task hot path);
        // shutdown is rare and 200µs granularity is plenty.
        loop {
            let i = &self.inner;
            if i.submitting.load(Ordering::SeqCst) == 0
                && i.pending.load(Ordering::SeqCst) == 0
                && i.active.load(Ordering::SeqCst) == 0
            {
                break;
            }
            std::thread::sleep(std::time::Duration::from_micros(200));
        }
        self.inner.terminate.store(true, Ordering::SeqCst);
        self.inner.wake_all();
        let handles =
            std::mem::take(&mut *self.workers.lock().unwrap_or_else(|e| e.into_inner()));
        for h in handles {
            let _ = h.join();
        }
        // Defensive sweep: run anything a pathological race could have
        // queued after the drain check (keys unschedule through run_key,
        // so no ticket is ever stranded even then).
        while let Some(r) = self.inner.pop_any() {
            self.inner.run(r, None);
        }
    }
}

impl Drop for WorkPool {
    fn drop(&mut self) {
        if self.inner.current_local().is_some() {
            // Dropped from one of this pool's own workers — possible when
            // the last engine holding this pool as its executor dies inside
            // a job after its service was dropped without shutdown. The
            // blocking drain would wait on the calling task itself (it is
            // part of `active`), so detach instead: reject new work, wake
            // everyone, and let the workers exit on their own (their
            // JoinHandles are simply dropped). Even detached, nothing may
            // be stranded: drain the queues on this thread and wait out
            // in-flight submissions — an external submitter woken by a pop
            // observes `closed` and fails cleanly, a worker-context
            // submitter never parks (it help-drains), and anything
            // re-queued by a still-running worker is drained by that
            // worker before it exits (workers only exit on empty queues).
            self.inner.closed.store(true, Ordering::SeqCst);
            self.inner.terminate.store(true, Ordering::SeqCst);
            self.inner.wake_all();
            loop {
                while let Some(r) = self.inner.pop_any() {
                    self.inner.run(r, None);
                }
                if self.inner.submitting.load(Ordering::SeqCst) == 0
                    && self.inner.pending.load(Ordering::SeqCst) == 0
                {
                    return;
                }
                std::thread::sleep(std::time::Duration::from_micros(100));
            }
        }
        self.shutdown();
    }
}

impl std::fmt::Debug for WorkPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("WorkPool").field("workers", &self.nworkers).finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU32;

    #[test]
    fn keyed_tasks_run_in_submission_order() {
        let pool = WorkPool::new(3);
        let key = pool.register_key("k", 4).unwrap();
        let log = Arc::new(Mutex::new(Vec::new()));
        for i in 0..50u32 {
            let log = log.clone();
            key.submit(move || log.lock().unwrap().push(i)).unwrap();
        }
        key.close();
        key.wait_idle();
        assert_eq!(*log.lock().unwrap(), (0..50).collect::<Vec<_>>());
        let ks = key.stats();
        assert_eq!(ks.submitted, 50);
        assert_eq!(ks.completed, 50);
        assert_eq!(ks.panicked, 0);
        pool.shutdown();
        assert_eq!(pool.stats().tasks_executed, 50);
    }

    #[test]
    fn independent_keys_spread_across_workers() {
        let pool = WorkPool::new(4);
        let barrier = Arc::new(std::sync::Barrier::new(4));
        // Four keys whose single tasks rendezvous: only possible if they
        // genuinely run concurrently on distinct workers.
        let keys: Vec<_> =
            (0..4).map(|i| pool.register_key(&format!("k{i}"), 1).unwrap()).collect();
        for key in &keys {
            let b = barrier.clone();
            key.submit(move || {
                b.wait();
            })
            .unwrap();
        }
        for key in &keys {
            key.wait_idle();
        }
        pool.shutdown();
    }

    #[test]
    fn panic_is_isolated_to_its_task() {
        let pool = WorkPool::new(2);
        let key = pool.register_key("flaky", 4).unwrap();
        let hits = Arc::new(AtomicU32::new(0));
        let h = hits.clone();
        key.submit(move || {
            h.fetch_add(1, Ordering::SeqCst);
        })
        .unwrap();
        key.submit(|| panic!("boom")).unwrap();
        let h = hits.clone();
        key.submit(move || {
            h.fetch_add(1, Ordering::SeqCst);
        })
        .unwrap();
        key.wait_idle();
        // Both healthy tasks ran despite the panic in between; the key and
        // the pool survived and counted it.
        assert_eq!(hits.load(Ordering::SeqCst), 2);
        assert_eq!(key.stats().panicked, 1);
        assert_eq!(key.stats().completed, 3);
        assert_eq!(pool.stats().panics, 1);
        // The pool still serves new keys afterwards.
        let k2 = pool.register_key("after", 2).unwrap();
        let h = hits.clone();
        k2.submit(move || {
            h.fetch_add(1, Ordering::SeqCst);
        })
        .unwrap();
        k2.wait_idle();
        assert_eq!(hits.load(Ordering::SeqCst), 3);
        pool.shutdown();
    }

    #[test]
    fn closed_key_rejects_but_drains() {
        let pool = WorkPool::new(1);
        let key = pool.register_key("k", 8).unwrap();
        let gate = Arc::new(Mutex::new(()));
        let count = Arc::new(AtomicU32::new(0));
        // Hold the single worker hostage so submissions stay queued.
        let held = gate.lock().unwrap();
        {
            let gate = gate.clone();
            key.submit(move || {
                drop(gate.lock().unwrap());
            })
            .unwrap();
        }
        for _ in 0..3 {
            let c = count.clone();
            key.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            })
            .unwrap();
        }
        key.close();
        assert!(key.submit(|| {}).is_err(), "closed key must reject");
        drop(held);
        key.wait_idle();
        assert_eq!(count.load(Ordering::SeqCst), 3, "accepted tasks drain after close");
        pool.shutdown();
    }

    #[test]
    fn shutdown_rejects_new_submissions_and_drains_queued() {
        let pool = WorkPool::new(2);
        let key = pool.register_key("k", 64).unwrap();
        let count = Arc::new(AtomicU32::new(0));
        for _ in 0..32 {
            let c = count.clone();
            key.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            })
            .unwrap();
        }
        pool.shutdown();
        assert_eq!(count.load(Ordering::SeqCst), 32, "shutdown must drain accepted tasks");
        assert!(key.submit(|| {}).is_err(), "pool rejects submissions after shutdown");
        assert!(pool.register_key("late", 1).is_err());
    }

    #[test]
    fn backpressure_blocks_then_completes() {
        let pool = WorkPool::new(1);
        let key = pool.register_key("bp", 1).unwrap();
        let done = Arc::new(AtomicU32::new(0));
        let submitter = {
            let key = key.clone();
            let done = done.clone();
            std::thread::spawn(move || {
                for _ in 0..64 {
                    let d = done.clone();
                    key.submit(move || {
                        // Slow-ish consumer: the cap-1 mailbox forces the
                        // submitter to block between pushes.
                        std::thread::sleep(std::time::Duration::from_micros(50));
                        d.fetch_add(1, Ordering::SeqCst);
                    })
                    .unwrap();
                }
            })
        };
        submitter.join().unwrap();
        key.wait_idle();
        assert_eq!(done.load(Ordering::SeqCst), 64);
        pool.shutdown();
    }

    #[test]
    fn self_submission_from_a_running_task_bypasses_the_bound() {
        // A task re-submitting to its OWN cap-1 key: waiting (or help-
        // draining) for a slot would spin forever, because only this very
        // worker could free it. Self-sends bypass the bound instead; FIFO
        // order is preserved.
        let pool = Arc::new(WorkPool::new(1));
        let key = pool.register_key("self", 1).unwrap();
        let log = Arc::new(Mutex::new(Vec::new()));
        {
            let resubmit = key.clone();
            let log = log.clone();
            key.submit(move || {
                log.lock().unwrap().push(0u32);
                for i in 1..=3u32 {
                    let log = log.clone();
                    resubmit.submit(move || log.lock().unwrap().push(i)).unwrap();
                }
            })
            .unwrap();
        }
        key.wait_idle();
        assert_eq!(*log.lock().unwrap(), vec![0, 1, 2, 3]);
        assert_eq!(key.stats().completed, 4);
        pool.shutdown();
    }

    #[test]
    fn worker_context_submit_on_full_mailbox_cannot_deadlock() {
        // The pool's ONLY worker runs a task that overfills another key's
        // cap-1 mailbox. Parking that worker would deadlock the pool (no
        // other worker exists to drain); the help-drain escape must run
        // the full key's tasks inline instead — and keep their FIFO order.
        let pool = Arc::new(WorkPool::new(1));
        let a = pool.register_key("a", 2).unwrap();
        let b = pool.register_key("b", 1).unwrap();
        let log = Arc::new(Mutex::new(Vec::new()));
        {
            let b = b.clone();
            let log = log.clone();
            a.submit(move || {
                for i in 0..4u32 {
                    let log = log.clone();
                    b.submit(move || log.lock().unwrap().push(i)).unwrap();
                }
            })
            .unwrap();
        }
        a.wait_idle();
        b.wait_idle();
        assert_eq!(*log.lock().unwrap(), vec![0, 1, 2, 3]);
        assert_eq!(pool.stats().panics, 0);
        pool.shutdown();
    }

    #[test]
    fn submitting_from_a_task_to_another_key_works() {
        let pool = Arc::new(WorkPool::new(2));
        let a = pool.register_key("a", 4).unwrap();
        let b = pool.register_key("b", 4).unwrap();
        let log = Arc::new(Mutex::new(Vec::new()));
        {
            let log = log.clone();
            let b = b.clone();
            a.submit(move || {
                log.lock().unwrap().push("a");
                let log = log.clone();
                b.submit(move || log.lock().unwrap().push("b")).unwrap();
            })
            .unwrap();
        }
        a.wait_idle();
        b.wait_idle();
        // b's task may only exist after a's ran.
        assert_eq!(*log.lock().unwrap(), vec!["a", "b"]);
        pool.shutdown();
    }
}
