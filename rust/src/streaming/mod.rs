//! Streaming ingestion: slice sources, batching and backpressure.
//!
//! The incremental setting of the paper is "updates arrive as new slices
//! over time". This module turns any slice producer into a batched stream
//! the engine consumes: a [`SliceSource`] yields frontal slices one at a
//! time; [`Batcher`] groups them into `TensorData` batches; and
//! [`StreamPump`] runs a source on a producer thread with a bounded queue —
//! if the decomposition falls behind, the producer blocks (backpressure)
//! instead of letting memory grow unboundedly.

use crate::tensor::{CooTensor, DenseTensor, Tensor3, TensorData};
use anyhow::Result;
use std::collections::VecDeque;
use std::sync::mpsc;

/// One incoming frontal slice: either dense `I×J` data (column-major, `i`
/// fastest) or sparse `(i, j, v)` triples.
#[derive(Clone, Debug)]
pub enum Slice {
    Dense { i: usize, j: usize, data: Vec<f64> },
    Sparse { i: usize, j: usize, entries: Vec<(u32, u32, f64)> },
}

impl Slice {
    pub fn dims(&self) -> (usize, usize) {
        match self {
            Slice::Dense { i, j, .. } | Slice::Sparse { i, j, .. } => (*i, *j),
        }
    }

    pub fn nnz(&self) -> usize {
        match self {
            Slice::Dense { data, .. } => data.iter().filter(|&&v| v != 0.0).count(),
            Slice::Sparse { entries, .. } => entries.len(),
        }
    }
}

/// A producer of slices (a growing mode-3 tensor source).
pub trait SliceSource: Send {
    /// `(I, J)` of every slice this source emits.
    fn slice_dims(&self) -> (usize, usize);
    /// Next slice, or `None` when the stream ends.
    fn next_slice(&mut self) -> Option<Slice>;
}

/// Adapts an owned tensor into a slice-by-slice replay (simulation of a
/// live feed; used by examples and the eval harness).
pub struct TensorReplay {
    tensor: TensorData,
    cursor: usize,
}

impl TensorReplay {
    pub fn new(tensor: TensorData) -> Self {
        TensorReplay { tensor, cursor: 0 }
    }
}

impl SliceSource for TensorReplay {
    fn slice_dims(&self) -> (usize, usize) {
        let (i, j, _) = self.tensor.dims();
        (i, j)
    }

    fn next_slice(&mut self) -> Option<Slice> {
        let (ni, nj, nk) = self.tensor.dims();
        if self.cursor >= nk {
            return None;
        }
        let k = self.cursor;
        self.cursor += 1;
        Some(match &self.tensor {
            TensorData::Dense(d) => {
                Slice::Dense { i: ni, j: nj, data: d.frontal_slice(k).to_vec() }
            }
            TensorData::Sparse(s) => {
                let entries = s
                    .iter()
                    .filter(|&(_, _, kk, _)| kk == k)
                    .map(|(i, j, _, v)| (i as u32, j as u32, v))
                    .collect();
                Slice::Sparse { i: ni, j: nj, entries }
            }
            // CSF's mode-3 fiber tree hands out a slice without scanning
            // the full entry list.
            TensorData::Csf(t) => Slice::Sparse { i: ni, j: nj, entries: t.slice_entries(k) },
        })
    }
}

/// Groups slices into batches of `batch_size` (the paper's "batch of
/// incoming slices"; the final partial batch is flushed at end of stream).
pub struct Batcher {
    batch_size: usize,
    sparse: bool,
    pending: VecDeque<Slice>,
}

impl Batcher {
    pub fn new(batch_size: usize, sparse: bool) -> Self {
        assert!(batch_size >= 1);
        Batcher { batch_size, sparse, pending: VecDeque::new() }
    }

    /// Add a slice; returns a full batch when ready.
    pub fn push(&mut self, s: Slice) -> Option<TensorData> {
        self.pending.push_back(s);
        if self.pending.len() >= self.batch_size {
            self.flush()
        } else {
            None
        }
    }

    /// Drain whatever is pending into a (possibly partial) batch.
    pub fn flush(&mut self) -> Option<TensorData> {
        if self.pending.is_empty() {
            return None;
        }
        let (ni, nj) = self.pending[0].dims();
        let nk = self.pending.len();
        let out = if self.sparse {
            let mut t = CooTensor::new(ni, nj, nk);
            for (k, s) in self.pending.drain(..).enumerate() {
                match s {
                    Slice::Sparse { entries, .. } => {
                        for (i, j, v) in entries {
                            t.push(i as usize, j as usize, k, v);
                        }
                    }
                    Slice::Dense { data, .. } => {
                        for j in 0..nj {
                            for i in 0..ni {
                                let v = data[i + ni * j];
                                if v != 0.0 {
                                    t.push(i, j, k, v);
                                }
                            }
                        }
                    }
                }
            }
            TensorData::Sparse(t)
        } else {
            let mut t = DenseTensor::zeros(ni, nj, nk);
            for (k, s) in self.pending.drain(..).enumerate() {
                match s {
                    Slice::Dense { data, .. } => {
                        for j in 0..nj {
                            for i in 0..ni {
                                t.set(i, j, k, data[i + ni * j]);
                            }
                        }
                    }
                    Slice::Sparse { entries, .. } => {
                        for (i, j, v) in entries {
                            t.set(i as usize, j as usize, k, v);
                        }
                    }
                }
            }
            TensorData::Dense(t)
        };
        // Large sparse batches promote to the CSF backend: the engine runs
        // its per-repetition MoI/extraction passes over them, and a CSF
        // batch merges tree-to-tree into a CSF accumulator (the incremental
        // append never round-trips either side through COO).
        Some(out.promoted())
    }
}

/// Runs a [`SliceSource`] on a producer thread, batching into a bounded
/// queue (`queue_cap` batches). `next_batch` blocks the consumer; a full
/// queue blocks the *producer* — backpressure instead of unbounded memory.
pub struct StreamPump {
    rx: mpsc::Receiver<TensorData>,
}

impl StreamPump {
    pub fn spawn<S: SliceSource + 'static>(
        mut source: S,
        batch_size: usize,
        sparse: bool,
        queue_cap: usize,
    ) -> Result<Self> {
        let (tx, rx) = mpsc::sync_channel::<TensorData>(queue_cap.max(1));
        std::thread::Builder::new().name("stream-pump".into()).spawn(move || {
            let mut batcher = Batcher::new(batch_size, sparse);
            while let Some(slice) = source.next_slice() {
                if let Some(batch) = batcher.push(slice) {
                    if tx.send(batch).is_err() {
                        return; // consumer hung up
                    }
                }
            }
            if let Some(batch) = batcher.flush() {
                let _ = tx.send(batch);
            }
        })?;
        Ok(StreamPump { rx })
    }

    /// Blocking pull; `None` at end of stream.
    pub fn next_batch(&self) -> Option<TensorData> {
        self.rx.recv().ok()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn replay_roundtrips_dense_tensor() {
        let mut rng = Rng::new(1);
        let t = DenseTensor::rand(4, 5, 6, &mut rng);
        let mut replay = TensorReplay::new(t.clone().into());
        let mut batcher = Batcher::new(6, false);
        let mut out = None;
        while let Some(s) = replay.next_slice() {
            if let Some(b) = batcher.push(s) {
                out = Some(b);
            }
        }
        let out = out.unwrap().to_dense();
        assert_eq!(out.data(), t.data());
    }

    #[test]
    fn batcher_emits_full_and_partial_batches() {
        let mut b = Batcher::new(3, false);
        let mk = || Slice::Dense { i: 2, j: 2, data: vec![1.0; 4] };
        assert!(b.push(mk()).is_none());
        assert!(b.push(mk()).is_none());
        let full = b.push(mk()).unwrap();
        assert_eq!(full.dims(), (2, 2, 3));
        assert!(b.push(mk()).is_none());
        let partial = b.flush().unwrap();
        assert_eq!(partial.dims(), (2, 2, 1));
        assert!(b.flush().is_none());
    }

    #[test]
    fn sparse_batching_preserves_entries() {
        let mut b = Batcher::new(2, true);
        let s0 = Slice::Sparse { i: 3, j: 3, entries: vec![(0, 1, 5.0), (2, 2, -1.0)] };
        let s1 = Slice::Sparse { i: 3, j: 3, entries: vec![(1, 0, 2.0)] };
        assert!(b.push(s0).is_none());
        let batch = b.push(s1).unwrap();
        assert!(batch.is_sparse());
        assert_eq!(batch.nnz(), 3);
        let d = batch.to_dense();
        assert_eq!(d.get(0, 1, 0), 5.0);
        assert_eq!(d.get(1, 0, 1), 2.0);
    }

    #[test]
    fn mixed_slice_kinds_into_dense_batch() {
        let mut b = Batcher::new(2, false);
        let s0 = Slice::Dense { i: 2, j: 1, data: vec![1.0, 2.0] };
        let s1 = Slice::Sparse { i: 2, j: 1, entries: vec![(1, 0, 7.0)] };
        b.push(s0);
        let batch = b.push(s1).unwrap();
        let d = batch.to_dense();
        assert_eq!(d.get(0, 0, 0), 1.0);
        assert_eq!(d.get(1, 0, 1), 7.0);
    }

    #[test]
    fn pump_streams_all_batches_with_backpressure() {
        let mut rng = Rng::new(2);
        let t = DenseTensor::rand(3, 3, 10, &mut rng);
        let pump = StreamPump::spawn(TensorReplay::new(t.clone().into()), 3, false, 1).unwrap();
        let mut total_k = 0;
        let mut count = 0;
        while let Some(b) = pump.next_batch() {
            total_k += b.dims().2;
            count += 1;
            // Slow consumer: the producer must block, not drop.
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        assert_eq!(total_k, 10);
        assert_eq!(count, 4); // 3+3+3+1
    }

    #[test]
    fn slice_nnz() {
        let s = Slice::Dense { i: 2, j: 2, data: vec![0.0, 1.0, 0.0, 2.0] };
        assert_eq!(s.nnz(), 2);
        let s = Slice::Sparse { i: 2, j: 2, entries: vec![(0, 0, 1.0)] };
        assert_eq!(s.nnz(), 1);
    }
}
