//! Streaming ingestion: slice sources, batching and backpressure.
//!
//! The incremental setting of the paper is "updates arrive as new slices
//! over time". This module turns any slice producer into a batched stream
//! the engine consumes: a [`SliceSource`] yields frontal slices one at a
//! time; [`Batcher`] groups them into `TensorData` batches; and
//! [`StreamPump`] runs a source on a producer thread with a bounded queue —
//! if the decomposition falls behind, the producer blocks (backpressure)
//! instead of letting memory grow unboundedly.

use crate::tensor::{CooTensor, DenseTensor, Tensor3, TensorData};
use anyhow::Result;
use std::collections::VecDeque;
use std::sync::mpsc;

/// One incoming frontal slice: either dense `I×J` data (column-major, `i`
/// fastest) or sparse `(i, j, v)` triples.
#[derive(Clone, Debug)]
pub enum Slice {
    Dense { i: usize, j: usize, data: Vec<f64> },
    Sparse { i: usize, j: usize, entries: Vec<(u32, u32, f64)> },
}

impl Slice {
    pub fn dims(&self) -> (usize, usize) {
        match self {
            Slice::Dense { i, j, .. } | Slice::Sparse { i, j, .. } => (*i, *j),
        }
    }

    pub fn nnz(&self) -> usize {
        match self {
            Slice::Dense { data, .. } => data.iter().filter(|&&v| v != 0.0).count(),
            Slice::Sparse { entries, .. } => entries.len(),
        }
    }
}

/// A producer of slices (a growing mode-3 tensor source).
pub trait SliceSource: Send {
    /// `(I, J)` of every slice this source emits.
    fn slice_dims(&self) -> (usize, usize);
    /// Next slice, or `None` when the stream ends.
    fn next_slice(&mut self) -> Option<Slice>;
}

/// Adapts an owned tensor into a slice-by-slice replay (simulation of a
/// live feed; used by examples and the eval harness).
pub struct TensorReplay {
    tensor: TensorData,
    cursor: usize,
}

impl TensorReplay {
    pub fn new(tensor: TensorData) -> Self {
        TensorReplay { tensor, cursor: 0 }
    }
}

impl SliceSource for TensorReplay {
    fn slice_dims(&self) -> (usize, usize) {
        let (i, j, _) = self.tensor.dims();
        (i, j)
    }

    fn next_slice(&mut self) -> Option<Slice> {
        let (ni, nj, nk) = self.tensor.dims();
        if self.cursor >= nk {
            return None;
        }
        let k = self.cursor;
        self.cursor += 1;
        Some(match &self.tensor {
            TensorData::Dense(d) => {
                Slice::Dense { i: ni, j: nj, data: d.frontal_slice(k).to_vec() }
            }
            TensorData::Sparse(s) => {
                let entries = s
                    .iter()
                    .filter(|&(_, _, kk, _)| kk == k)
                    .map(|(i, j, _, v)| (i as u32, j as u32, v))
                    .collect();
                Slice::Sparse { i: ni, j: nj, entries }
            }
            // CSF's mode-3 fiber tree hands out a slice without scanning
            // the full entry list.
            TensorData::Csf(t) => Slice::Sparse { i: ni, j: nj, entries: t.slice_entries(k) },
        })
    }
}

/// Groups slices into batches of `batch_size` (the paper's "batch of
/// incoming slices"; the final partial batch is flushed at end of stream).
///
/// Every slice is validated on [`push`](Self::push): the stream's `(I, J)`
/// is pinned by the first slice (or up front via
/// [`with_dims`](Self::with_dims)) and later slices must match, dense
/// payloads must carry exactly `I·J` values, and sparse entries must index
/// inside the slice. Without this, a mismatched slice would silently write
/// out of range (growing the batch tensor's logical dims) or truncate.
pub struct Batcher {
    batch_size: usize,
    sparse: bool,
    /// `(I, J)` contract for the stream; pinned by the first slice.
    dims: Option<(usize, usize)>,
    /// nnz bar for COO→CSF promotion of emitted batches (defaults to
    /// [`crate::tensor::CSF_PROMOTION_NNZ`]; see
    /// [`with_promotion_bar`](Self::with_promotion_bar)).
    promotion_bar: usize,
    pending: VecDeque<Slice>,
}

impl Batcher {
    pub fn new(batch_size: usize, sparse: bool) -> Self {
        assert!(batch_size >= 1);
        Batcher {
            batch_size,
            sparse,
            dims: None,
            promotion_bar: crate::tensor::CSF_PROMOTION_NNZ,
            pending: VecDeque::new(),
        }
    }

    /// A batcher with the `(I, J)` contract pinned up front (e.g. from
    /// [`SliceSource::slice_dims`]), so even the first slice is validated.
    pub fn with_dims(batch_size: usize, sparse: bool, dims: (usize, usize)) -> Self {
        let mut b = Self::new(batch_size, sparse);
        b.dims = Some(dims);
        b
    }

    /// Override the COO→CSF promotion bar for emitted batches — pair it
    /// with `SamBaTenConfig`'s `csf_nnz_bar` so a stream and its engine
    /// agree on the break-even.
    pub fn with_promotion_bar(mut self, bar: usize) -> Self {
        self.promotion_bar = bar.max(1);
        self
    }

    /// Add a slice; returns a full batch when ready, or an error for a
    /// malformed slice (which is dropped — the batcher state is unchanged
    /// and subsequent well-formed slices keep working).
    pub fn push(&mut self, s: Slice) -> Result<Option<TensorData>> {
        // Internal consistency first — a malformed slice must be rejected
        // WITHOUT pinning the stream dims, or a bad first slice would
        // poison every well-formed slice after it.
        match &s {
            Slice::Dense { i, j, data } => anyhow::ensure!(
                data.len() == i * j,
                "dense slice carries {} values for an {i}x{j} slice",
                data.len()
            ),
            Slice::Sparse { i, j, entries } => {
                for &(ei, ej, _) in entries {
                    anyhow::ensure!(
                        (ei as usize) < *i && (ej as usize) < *j,
                        "sparse entry ({ei}, {ej}) out of range for an {i}x{j} slice"
                    );
                }
            }
        }
        let (si, sj) = s.dims();
        match self.dims {
            Some((ni, nj)) => anyhow::ensure!(
                (si, sj) == (ni, nj),
                "slice dims {si}x{sj} do not match the stream's {ni}x{nj}"
            ),
            None => self.dims = Some((si, sj)),
        }
        self.pending.push_back(s);
        Ok(if self.pending.len() >= self.batch_size { self.flush() } else { None })
    }

    /// Drain whatever is pending into a (possibly partial) batch.
    pub fn flush(&mut self) -> Option<TensorData> {
        if self.pending.is_empty() {
            return None;
        }
        // Every pending slice was validated against the pinned dims.
        let (ni, nj) = self.dims.expect("dims pinned by the first push");
        let nk = self.pending.len();
        let out = if self.sparse {
            let mut t = CooTensor::new(ni, nj, nk);
            for (k, s) in self.pending.drain(..).enumerate() {
                match s {
                    Slice::Sparse { entries, .. } => {
                        for (i, j, v) in entries {
                            t.push(i as usize, j as usize, k, v);
                        }
                    }
                    Slice::Dense { data, .. } => {
                        for j in 0..nj {
                            for i in 0..ni {
                                let v = data[i + ni * j];
                                if v != 0.0 {
                                    t.push(i, j, k, v);
                                }
                            }
                        }
                    }
                }
            }
            TensorData::Sparse(t)
        } else {
            let mut t = DenseTensor::zeros(ni, nj, nk);
            for (k, s) in self.pending.drain(..).enumerate() {
                match s {
                    Slice::Dense { data, .. } => {
                        for j in 0..nj {
                            for i in 0..ni {
                                t.set(i, j, k, data[i + ni * j]);
                            }
                        }
                    }
                    Slice::Sparse { entries, .. } => {
                        // Duplicate coordinates within a slice must coalesce
                        // by summation — the same contract as the sparse arm's
                        // `CooTensor::push` — not last-write-wins, which would
                        // make the batch depend on entry order.
                        for (i, j, v) in entries {
                            t.add_at(i as usize, j as usize, k, v);
                        }
                    }
                }
            }
            TensorData::Dense(t)
        };
        // Large sparse batches promote to the CSF backend: the engine runs
        // its per-repetition MoI/extraction passes over them, and a CSF
        // batch merges tree-to-tree into a CSF accumulator (the incremental
        // append never round-trips either side through COO).
        Some(out.promoted_at(self.promotion_bar))
    }
}

/// Runs a [`SliceSource`] on a producer thread, batching into a bounded
/// queue (`queue_cap` batches). `next_batch` blocks the consumer; a full
/// queue blocks the *producer* — backpressure instead of unbounded memory.
///
/// A malformed slice (see [`Batcher::push`]) terminates the stream with an
/// `Err` item: the consumer observes the error in order, after every batch
/// that was already well-formed.
pub struct StreamPump {
    rx: mpsc::Receiver<Result<TensorData>>,
}

impl StreamPump {
    pub fn spawn<S: SliceSource + 'static>(
        source: S,
        batch_size: usize,
        sparse: bool,
        queue_cap: usize,
    ) -> Result<Self> {
        let bar = crate::tensor::CSF_PROMOTION_NNZ;
        Self::spawn_with_promotion_bar(source, batch_size, sparse, queue_cap, bar)
    }

    /// [`StreamPump::spawn`] with an explicit COO→CSF promotion bar for
    /// the emitted batches — pass `SamBaTenConfig::csf_nnz_bar()` so the
    /// stream and the engine consuming it agree on the break-even.
    pub fn spawn_with_promotion_bar<S: SliceSource + 'static>(
        mut source: S,
        batch_size: usize,
        sparse: bool,
        queue_cap: usize,
        promotion_bar: usize,
    ) -> Result<Self> {
        let (tx, rx) = mpsc::sync_channel::<Result<TensorData>>(queue_cap.max(1));
        let dims = source.slice_dims();
        std::thread::Builder::new().name("stream-pump".into()).spawn(move || {
            let mut batcher =
                Batcher::with_dims(batch_size, sparse, dims).with_promotion_bar(promotion_bar);
            while let Some(slice) = source.next_slice() {
                match batcher.push(slice) {
                    Ok(Some(batch)) => {
                        if tx.send(Ok(batch)).is_err() {
                            return; // consumer hung up
                        }
                    }
                    Ok(None) => {}
                    Err(e) => {
                        // Surface the malformed slice and end the stream —
                        // a source that breaks its own dims contract cannot
                        // be trusted to keep feeding the engine.
                        let _ = tx.send(Err(e));
                        return;
                    }
                }
            }
            if let Some(batch) = batcher.flush() {
                let _ = tx.send(Ok(batch));
            }
        })?;
        Ok(StreamPump { rx })
    }

    /// Blocking pull; `None` at end of stream, `Some(Err(..))` if the
    /// source emitted a malformed slice (the stream ends after it).
    pub fn next_batch(&self) -> Option<Result<TensorData>> {
        self.rx.recv().ok()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn replay_roundtrips_dense_tensor() {
        let mut rng = Rng::new(1);
        let t = DenseTensor::rand(4, 5, 6, &mut rng);
        let mut replay = TensorReplay::new(t.clone().into());
        let mut batcher = Batcher::new(6, false);
        let mut out = None;
        while let Some(s) = replay.next_slice() {
            if let Some(b) = batcher.push(s).unwrap() {
                out = Some(b);
            }
        }
        let out = out.unwrap().to_dense();
        assert_eq!(out.data(), t.data());
    }

    #[test]
    fn batcher_emits_full_and_partial_batches() {
        let mut b = Batcher::new(3, false);
        let mk = || Slice::Dense { i: 2, j: 2, data: vec![1.0; 4] };
        assert!(b.push(mk()).unwrap().is_none());
        assert!(b.push(mk()).unwrap().is_none());
        let full = b.push(mk()).unwrap().unwrap();
        assert_eq!(full.dims(), (2, 2, 3));
        assert!(b.push(mk()).unwrap().is_none());
        let partial = b.flush().unwrap();
        assert_eq!(partial.dims(), (2, 2, 1));
        assert!(b.flush().is_none());
    }

    #[test]
    fn sparse_batching_preserves_entries() {
        let mut b = Batcher::new(2, true);
        let s0 = Slice::Sparse { i: 3, j: 3, entries: vec![(0, 1, 5.0), (2, 2, -1.0)] };
        let s1 = Slice::Sparse { i: 3, j: 3, entries: vec![(1, 0, 2.0)] };
        assert!(b.push(s0).unwrap().is_none());
        let batch = b.push(s1).unwrap().unwrap();
        assert!(batch.is_sparse());
        assert_eq!(batch.nnz(), 3);
        let d = batch.to_dense();
        assert_eq!(d.get(0, 1, 0), 5.0);
        assert_eq!(d.get(1, 0, 1), 2.0);
    }

    #[test]
    fn batcher_promotion_bar_is_configurable() {
        let slices = || {
            [
                Slice::Sparse { i: 3, j: 3, entries: vec![(0, 0, 1.0), (1, 1, 2.0)] },
                Slice::Sparse { i: 3, j: 3, entries: vec![(2, 2, 3.0)] },
            ]
        };
        // Default bar (16 Ki): a 3-nnz batch stays COO.
        let mut b = Batcher::new(2, true);
        let [s0, s1] = slices();
        b.push(s0).unwrap();
        let batch = b.push(s1).unwrap().unwrap();
        assert!(batch.is_sparse() && !batch.is_csf());
        // A lowered bar promotes the identical batch to CSF.
        let mut b = Batcher::new(2, true).with_promotion_bar(2);
        let [s0, s1] = slices();
        b.push(s0).unwrap();
        let batch = b.push(s1).unwrap().unwrap();
        assert!(batch.is_csf());
        assert_eq!(batch.nnz(), 3);
    }

    #[test]
    fn mixed_slice_kinds_into_dense_batch() {
        let mut b = Batcher::new(2, false);
        let s0 = Slice::Dense { i: 2, j: 1, data: vec![1.0, 2.0] };
        let s1 = Slice::Sparse { i: 2, j: 1, entries: vec![(1, 0, 7.0)] };
        b.push(s0).unwrap();
        let batch = b.push(s1).unwrap().unwrap();
        let d = batch.to_dense();
        assert_eq!(d.get(0, 0, 0), 1.0);
        assert_eq!(d.get(1, 0, 1), 7.0);
    }

    #[test]
    fn duplicate_coordinates_coalesce_identically_in_both_arms() {
        // A slice that revisits (0, 0) and (1, 1); both the dense and the
        // sparse arm must sum duplicates, independent of entry order.
        let fwd = vec![(0u32, 0u32, 1.0), (1, 1, 10.0), (0, 0, 2.0), (1, 1, -4.0)];
        let mut rev = fwd.clone();
        rev.reverse();
        for entries in [fwd, rev] {
            for sparse in [false, true] {
                let mut b = Batcher::new(1, sparse);
                let batch =
                    b.push(Slice::Sparse { i: 2, j: 2, entries: entries.clone() }).unwrap().unwrap();
                let d = batch.to_dense();
                assert_eq!(d.get(0, 0, 0), 3.0);
                assert_eq!(d.get(1, 1, 0), 6.0);
                assert_eq!(d.get(0, 1, 0), 0.0);
            }
        }
    }

    #[test]
    fn batcher_rejects_mismatched_slice_dims() {
        let mut b = Batcher::new(4, false);
        b.push(Slice::Dense { i: 2, j: 2, data: vec![1.0; 4] }).unwrap();
        // Wrong (I, J) against the pinned stream dims.
        let err = b.push(Slice::Dense { i: 3, j: 2, data: vec![1.0; 6] });
        assert!(err.is_err());
        assert!(err.unwrap_err().to_string().contains("do not match"));
        // The bad slice was dropped; well-formed slices keep flowing and
        // the batch holds only validated ones.
        b.push(Slice::Dense { i: 2, j: 2, data: vec![2.0; 4] }).unwrap();
        assert_eq!(b.flush().unwrap().dims(), (2, 2, 2));
    }

    #[test]
    fn batcher_rejects_internally_inconsistent_slices() {
        // Dense payload of the wrong length (would silently truncate or
        // read out of range when written into the batch tensor).
        let mut b = Batcher::new(2, false);
        assert!(b.push(Slice::Dense { i: 2, j: 2, data: vec![1.0; 3] }).is_err());
        // The rejected slice must NOT have pinned the stream dims: a
        // well-formed slice of a different shape still opens the stream.
        assert!(b.push(Slice::Dense { i: 3, j: 3, data: vec![1.0; 9] }).is_ok());
        // Sparse entry indexing outside the slice (would write out of
        // range into the batch tensor).
        let mut b = Batcher::new(2, true);
        assert!(b.push(Slice::Sparse { i: 2, j: 2, entries: vec![(2, 0, 1.0)] }).is_err());
        assert!(b.push(Slice::Sparse { i: 2, j: 2, entries: vec![(0, 5, 1.0)] }).is_err());
        // In-range entries are fine.
        assert!(b.push(Slice::Sparse { i: 2, j: 2, entries: vec![(1, 1, 1.0)] }).is_ok());
    }

    #[test]
    fn batcher_with_dims_validates_first_slice() {
        let mut b = Batcher::with_dims(2, false, (4, 4));
        assert!(b.push(Slice::Dense { i: 2, j: 2, data: vec![1.0; 4] }).is_err());
        assert!(b.push(Slice::Dense { i: 4, j: 4, data: vec![1.0; 16] }).is_ok());
    }

    #[test]
    fn pump_streams_all_batches_with_backpressure() {
        let mut rng = Rng::new(2);
        let t = DenseTensor::rand(3, 3, 10, &mut rng);
        let pump = StreamPump::spawn(TensorReplay::new(t.clone().into()), 3, false, 1).unwrap();
        let mut total_k = 0;
        let mut count = 0;
        while let Some(b) = pump.next_batch() {
            total_k += b.unwrap().dims().2;
            count += 1;
            // Slow consumer: the producer must block, not drop.
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        assert_eq!(total_k, 10);
        assert_eq!(count, 4); // 3+3+3+1
    }

    #[test]
    fn pump_threads_promotion_bar_to_batches() {
        let mut rng = Rng::new(9);
        let t = CooTensor::rand(6, 6, 4, 0.5, &mut rng);
        let replay = TensorReplay::new(t.into());
        let pump = StreamPump::spawn_with_promotion_bar(replay, 2, true, 2, 1).unwrap();
        let mut slices = 0;
        while let Some(b) = pump.next_batch() {
            let b = b.unwrap();
            assert!(b.is_csf(), "bar 1 must promote every emitted batch");
            slices += b.dims().2;
        }
        assert_eq!(slices, 4);
    }

    #[test]
    fn pump_surfaces_malformed_source_as_error() {
        /// A source that violates its own dims contract on the 4th slice.
        struct LyingSource {
            emitted: usize,
        }
        impl SliceSource for LyingSource {
            fn slice_dims(&self) -> (usize, usize) {
                (2, 2)
            }
            fn next_slice(&mut self) -> Option<Slice> {
                self.emitted += 1;
                match self.emitted {
                    1..=3 => Some(Slice::Dense { i: 2, j: 2, data: vec![1.0; 4] }),
                    4 => Some(Slice::Dense { i: 3, j: 3, data: vec![1.0; 9] }),
                    _ => None,
                }
            }
        }
        let pump = StreamPump::spawn(LyingSource { emitted: 0 }, 2, false, 2).unwrap();
        // First batch (slices 1-2) is fine.
        assert!(pump.next_batch().unwrap().is_ok());
        // The stream then terminates with the validation error (slice 3 was
        // still pending — a partial batch is not flushed past an error).
        let err = pump.next_batch().unwrap();
        assert!(err.is_err());
        assert!(pump.next_batch().is_none(), "stream ends after the error");
    }

    #[test]
    fn slice_nnz() {
        let s = Slice::Dense { i: 2, j: 2, data: vec![0.0, 1.0, 0.0, 2.0] };
        assert_eq!(s.nnz(), 2);
        let s = Slice::Sparse { i: 2, j: 2, entries: vec![(0, 0, 1.0)] };
        assert_eq!(s.nnz(), 1);
    }
}
