//! Quality control (§III-B): the Core Consistency Diagnostic (CORCONDIA)
//! and GETRANK (Algorithm 2), which estimates the actual rank `R_new` of an
//! incoming sample so rank-deficient updates do not pollute the factors.
//!
//! CORCONDIA [Bro & Kiers 2003] rates a CP model by computing the Tucker
//! core `G = X ×₁ Ã⁺ ×₂ B⁺ ×₃ C⁺` (λ absorbed into Ã) and measuring how far
//! `G` is from the superdiagonal identity the CP model implies:
//! `corcondia = 100 · (1 − Σ(G − I)² / R)`. A perfect CP structure scores
//! 100; overfactored/broken models score low or negative.
//!
//! The paper uses a sparsity-exploiting CORCONDIA [19] because it diagnoses
//! *full* tensors; here the diagnostic only ever runs on SamBaTen's sampled
//! sub-tensors, which are bank-shaped and small, so the dense computation is
//! cheap (see DESIGN.md §4).

use crate::cp::{cp_als_with, AlsOptions, AlsWorkspace, CpModel};
use crate::linalg::pinv;
use crate::tensor::{DenseTensor, Tensor3, TensorData};
use anyhow::Result;

/// Core Consistency Diagnostic of `model` for tensor `x`. Returns a value
/// `≤ 100` (can be negative for badly mis-specified models).
pub fn corcondia(x: &DenseTensor, model: &CpModel) -> f64 {
    let r = model.rank();
    if r == 0 {
        return 0.0;
    }
    // Absorb λ into A so the implied core is the identity superdiagonal.
    let mut a = model.factors[0].clone();
    for t in 0..r {
        a.scale_col(t, model.lambda[t]);
    }
    let ap = pinv(&a, None);
    let bp = pinv(&model.factors[1], None);
    let cp = pinv(&model.factors[2], None);
    let g = x.ttm(0, &ap).ttm(1, &bp).ttm(2, &cp);
    let mut ssq = 0.0;
    for p in 0..r {
        for q in 0..r {
            for s in 0..r {
                let target = if p == q && q == s { 1.0 } else { 0.0 };
                let d = g.get(p, q, s) - target;
                ssq += d * d;
            }
        }
    }
    100.0 * (1.0 - ssq / r as f64)
}

/// Options for [`getrank`].
#[derive(Clone, Debug)]
pub struct GetRankOptions {
    /// Maximum candidate rank (the paper passes the universal rank `R`).
    pub max_rank: usize,
    /// CP runs per candidate rank (`it` in Algorithm 2).
    pub iterations: usize,
    /// A candidate rank is *acceptable* when its best CORCONDIA score is at
    /// least this threshold; GETRANK returns the largest acceptable rank.
    /// (Algorithm 2's "sort p, take top-1" degenerates to rank 1 if read
    /// literally — rank-1 models always score 100 — so, as in the CORCONDIA
    /// literature, we operationalise it as "largest rank that still has
    /// near-perfect core consistency".)
    pub threshold: f64,
    /// ALS options for the trial decompositions (kept cheap).
    pub als: AlsOptions,
    pub seed: u64,
}

impl Default for GetRankOptions {
    fn default() -> Self {
        GetRankOptions {
            max_rank: 5,
            iterations: 2,
            threshold: 80.0,
            als: AlsOptions { max_iters: 50, tol: 1e-4, ..Default::default() },
            seed: 0,
        }
    }
}

/// GETRANK (Algorithm 2): estimate the number of CP components in `x` by
/// scoring trial decompositions of rank `1..=max_rank` with CORCONDIA.
pub fn getrank(x: &TensorData, opts: &GetRankOptions) -> Result<usize> {
    getrank_with(x, opts, &mut AlsWorkspace::new())
}

/// [`getrank`] reusing a caller-owned [`AlsWorkspace`] across all
/// `max_rank · iterations` trial decompositions — in the engine, the same
/// per-repetition workspace the sample decomposition uses.
pub fn getrank_with(
    x: &TensorData,
    opts: &GetRankOptions,
    ws: &mut AlsWorkspace,
) -> Result<usize> {
    let dense = x.to_dense();
    let (ni, nj, nk) = dense.dims();
    let cap = opts.max_rank.min(ni).min(nj).min(nk).max(1);
    let mut best_rank = 1usize;
    for rank in 1..=cap {
        let mut best_score = f64::NEG_INFINITY;
        for j in 0..opts.iterations {
            let als = AlsOptions {
                seed: opts
                    .seed
                    .wrapping_add(rank as u64)
                    .wrapping_mul(0x9E37_79B9)
                    .wrapping_add(j as u64),
                ..opts.als.clone()
            };
            let (model, _) = cp_als_with(x, rank, &als, ws)?;
            let score = corcondia(&dense, &model);
            best_score = best_score.max(score);
        }
        if rank == 1 || best_score >= opts.threshold {
            best_rank = best_rank.max(rank);
        }
    }
    Ok(best_rank)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cp::cp_als;
    use crate::linalg::Matrix;
    use crate::util::Rng;

    fn exact_rank_tensor(dim: usize, r: usize, seed: u64) -> (DenseTensor, CpModel) {
        let mut rng = Rng::new(seed);
        let m = CpModel::new(
            Matrix::rand_gaussian(dim, r, &mut rng),
            Matrix::rand_gaussian(dim, r, &mut rng),
            Matrix::rand_gaussian(dim, r, &mut rng),
            vec![1.0; r],
        );
        (m.to_dense(), m)
    }

    #[test]
    fn perfect_model_scores_100() {
        let (x, truth) = exact_rank_tensor(8, 3, 1);
        let s = corcondia(&x, &truth);
        assert!((s - 100.0).abs() < 1e-6, "score {s}");
    }

    #[test]
    fn fitted_model_at_true_rank_scores_high() {
        let (x, _) = exact_rank_tensor(8, 2, 2);
        let xd: TensorData = x.clone().into();
        let (model, _) = cp_als(&xd, 2, &AlsOptions::default().with_seed(3)).unwrap();
        let s = corcondia(&x, &model);
        assert!(s > 95.0, "score {s}");
    }

    #[test]
    fn overfactored_model_scores_low() {
        let (x, _) = exact_rank_tensor(8, 2, 4);
        let xd: TensorData = x.clone().into();
        let (model, _) = cp_als(&xd, 4, &AlsOptions::quick().with_seed(5)).unwrap();
        let s = corcondia(&x, &model);
        assert!(s < 80.0, "overfactored score {s}");
    }

    #[test]
    fn getrank_recovers_true_rank() {
        for true_rank in [1usize, 2, 3] {
            let (x, _) = exact_rank_tensor(10, true_rank, 6 + true_rank as u64);
            let got = getrank(
                &x.into(),
                &GetRankOptions { max_rank: 5, iterations: 2, ..Default::default() },
            )
            .unwrap();
            assert_eq!(got, true_rank, "true rank {true_rank}");
        }
    }

    #[test]
    fn getrank_caps_at_dimensions() {
        let (x, _) = exact_rank_tensor(3, 2, 9);
        let got = getrank(
            &x.into(),
            &GetRankOptions { max_rank: 10, iterations: 1, ..Default::default() },
        )
        .unwrap();
        assert!(got <= 3);
    }

    #[test]
    fn ttm_matches_unfold_matmul() {
        // Sanity for the helper: X ×₁ M unfolds to M · X₍₁₎.
        let mut rng = Rng::new(10);
        let x = DenseTensor::rand(4, 5, 6, &mut rng);
        let m = Matrix::rand_gaussian(3, 4, &mut rng);
        let y = x.ttm(0, &m);
        let expect = m.matmul(&x.unfold(0));
        assert!(y.unfold(0).max_abs_diff(&expect) < 1e-10);
        let m2 = Matrix::rand_gaussian(2, 5, &mut rng);
        let y2 = x.ttm(1, &m2);
        assert!(y2.unfold(1).max_abs_diff(&m2.matmul(&x.unfold(1))) < 1e-10);
        let m3 = Matrix::rand_gaussian(2, 6, &mut rng);
        let y3 = x.ttm(2, &m3);
        assert!(y3.unfold(2).max_abs_diff(&m3.matmul(&x.unfold(2))) < 1e-10);
    }

    #[test]
    fn corcondia_noise_robustness_ordering() {
        // With mild noise, true rank still scores clearly above overfactored.
        let (clean, _) = exact_rank_tensor(9, 2, 11);
        let mut rng = Rng::new(12);
        let mut x = clean.clone();
        for v in x.data_mut() {
            *v += 0.02 * rng.gaussian();
        }
        let xd: TensorData = x.clone().into();
        let (m2, _) = cp_als(&xd, 2, &AlsOptions::quick().with_seed(13)).unwrap();
        let (m4, _) = cp_als(&xd, 4, &AlsOptions::quick().with_seed(14)).unwrap();
        let s2 = corcondia(&x, &m2);
        let s4 = corcondia(&x, &m4);
        assert!(s2 > s4, "s2={s2} s4={s4}");
    }
}
