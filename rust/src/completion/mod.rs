//! Online tensor completion: sparse *observation* ingest (GOCPT-style,
//! arXiv:2205.03749) next to the append-only slice path.
//!
//! SamBaTen's native ingest contract is fully-observed frontal slices
//! appended along mode 3. Real workloads from the paper's motivating
//! domains (ratings, social interactions, sensor feeds) instead deliver
//! sparse `(i, j, k, value)` **observations** of an underlying tensor —
//! values for existing cells, including *revisits* that overwrite a
//! previously observed cell. This module is the ingest type for that
//! second update shape:
//!
//! * [`ObservationBatch`] — a validated, deterministically coalesced set
//!   of cell observations (last write wins within a batch, by push
//!   order);
//! * [`CompletionConfig`] — the engine knob set, **off by default**; with
//!   completion off the engine is bit-identical to a build without this
//!   module (pinned in `tests/completion_stream.rs`).
//!
//! The solve itself — masked per-row normal equations restricted to the
//! observed support — lives in [`crate::cp::masked`] on top of the
//! backends' `masked_normals_into` kernel ([`crate::tensor::Tensor3`]);
//! the engine wiring is `SamBaTen::ingest_observations`
//! ([`crate::coordinator::engine`]). DESIGN.md §12 has the math.

use crate::tensor::CooTensor;
use anyhow::{bail, Result};

/// Engine configuration for the completion path. Defaults are **off**:
/// a default-constructed config leaves the engine's slice path
/// bit-identical to a completion-free build, and observation ingest is
/// rejected until `enabled` is set.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CompletionConfig {
    /// Accept [`ObservationBatch`] ingest. Off by default.
    pub enabled: bool,
    /// Masked ALS sweeps per observation batch (warm-started from the
    /// current model, over the full accumulated observation set).
    pub sweeps: usize,
    /// Per-row Tikhonov ridge, scaled by the mean diagonal of each row's
    /// masked normal matrix. Sparse fibers (few observations at a row)
    /// make individual row systems rank-deficient long before the global
    /// Gram is — the ridge keeps every observed row solvable.
    pub ridge: f64,
}

impl Default for CompletionConfig {
    fn default() -> Self {
        CompletionConfig { enabled: false, sweeps: 3, ridge: 1e-9 }
    }
}

impl CompletionConfig {
    /// An enabled config with the default solve knobs.
    pub fn enabled() -> Self {
        CompletionConfig { enabled: true, ..Default::default() }
    }

    /// Validate the knob ranges (mirrors `SamBaTenConfigBuilder::build`).
    pub fn validate(&self) -> Result<()> {
        if self.sweeps == 0 {
            bail!("completion.sweeps must be >= 1");
        }
        if !self.ridge.is_finite() || self.ridge < 0.0 {
            bail!("completion.ridge must be finite and >= 0, got {}", self.ridge);
        }
        Ok(())
    }
}

/// A batch of sparse cell observations `(i, j, k, value)` against a tensor
/// of fixed `dims` — the completion counterpart of a slice batch.
///
/// Invariants (enforced at construction, relied on by the engine, the
/// wire codec and the masked kernels):
///
/// * every index is in range for `dims`;
/// * every value is finite;
/// * coordinates are unique and sorted by `(k, j, i)` — duplicates within
///   one batch coalesce **deterministically, last push wins** (a cell
///   re-observed inside a batch keeps its latest value, independent of
///   any sort order). This is the observation-semantics counterpart of
///   the slice path's sum-coalesce: values are *states*, not increments.
///
/// Exact-zero values are kept: "observed as zero" is information the mask
/// must carry (unlike sparse tensor entries, where zero means absent).
#[derive(Clone, Debug, PartialEq)]
pub struct ObservationBatch {
    dims: (usize, usize, usize),
    entries: Vec<(u32, u32, u32, f64)>,
}

impl ObservationBatch {
    /// Empty batch against a `dims`-shaped tensor.
    pub fn new(dims: (usize, usize, usize)) -> Self {
        ObservationBatch { dims, entries: Vec::new() }
    }

    /// Build from raw entries, validating and coalescing. The entry order
    /// is the observation order: on duplicate coordinates the **last**
    /// entry wins.
    pub fn from_entries(
        dims: (usize, usize, usize),
        entries: Vec<(u32, u32, u32, f64)>,
    ) -> Result<Self> {
        let mut b = ObservationBatch { dims, entries };
        for &(i, j, k, v) in &b.entries {
            check_entry(dims, i, j, k, v)?;
        }
        b.coalesce();
        Ok(b)
    }

    /// Record one observation. Later pushes of the same cell overwrite
    /// earlier ones at [`ObservationBatch::coalesce`] time (which every
    /// consumer-facing constructor and the engine run implicitly).
    pub fn push(&mut self, i: usize, j: usize, k: usize, v: f64) -> Result<()> {
        check_entry(self.dims, i as u32, j as u32, k as u32, v)?;
        self.entries.push((i as u32, j as u32, k as u32, v));
        Ok(())
    }

    /// Deterministic duplicate resolution: sort by `(k, j, i)` and keep,
    /// for each coordinate, the value of the **latest push**. Stable sort
    /// preserves push order within a coordinate, so "last wins" is
    /// independent of how the duplicates interleave with other cells.
    pub fn coalesce(&mut self) {
        self.entries.sort_by_key(|&(i, j, k, _)| (k, j, i));
        // After a stable sort equal coordinates sit adjacent in push
        // order; dedup keeps the first of each run, so walk runs and keep
        // the last instead.
        let mut out: Vec<(u32, u32, u32, f64)> = Vec::with_capacity(self.entries.len());
        for &e in &self.entries {
            match out.last_mut() {
                Some(last) if (last.0, last.1, last.2) == (e.0, e.1, e.2) => *last = e,
                _ => out.push(e),
            }
        }
        self.entries = out;
    }

    pub fn dims(&self) -> (usize, usize, usize) {
        self.dims
    }

    /// Number of (coalesced) observations.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// The coalesced entries, sorted by `(k, j, i)`.
    pub fn entries(&self) -> &[(u32, u32, u32, f64)] {
        &self.entries
    }

    /// Entry iterator `(i, j, k, v)` in `(k, j, i)` order.
    pub fn iter(&self) -> impl Iterator<Item = (usize, usize, usize, f64)> + '_ {
        self.entries.iter().map(|&(i, j, k, v)| (i as usize, j as usize, k as usize, v))
    }
}

fn check_entry(dims: (usize, usize, usize), i: u32, j: u32, k: u32, v: f64) -> Result<()> {
    if (i as usize) >= dims.0 || (j as usize) >= dims.1 || (k as usize) >= dims.2 {
        bail!(
            "observation ({i}, {j}, {k}) out of range for a {}x{}x{} tensor",
            dims.0,
            dims.1,
            dims.2
        );
    }
    if !v.is_finite() {
        bail!("observation ({i}, {j}, {k}) has non-finite value {v}");
    }
    Ok(())
}

/// Accumulated observation state: the engine's view of every cell observed
/// so far, kept sorted by `(k, j, i)` with unique coordinates. Batches
/// merge in with last-write-wins *across* batches too — a revisit
/// overwrites the cell's previous value, it does not sum.
#[derive(Clone, Debug, Default)]
pub struct ObservationSet {
    dims: (usize, usize, usize),
    entries: Vec<(u32, u32, u32, f64)>,
}

impl ObservationSet {
    pub fn new(dims: (usize, usize, usize)) -> Self {
        ObservationSet { dims, entries: Vec::new() }
    }

    pub fn dims(&self) -> (usize, usize, usize) {
        self.dims
    }

    /// Track the stream's growing tensor: slice ingest appends mode-3
    /// rows, and later observation batches address the grown shape. Dims
    /// may only grow — every stored observation stays in range.
    pub fn grow_to(&mut self, dims: (usize, usize, usize)) -> Result<()> {
        if dims.0 < self.dims.0 || dims.1 < self.dims.1 || dims.2 < self.dims.2 {
            bail!(
                "observation set dims can only grow (have {:?}, asked to shrink to {:?})",
                self.dims,
                dims
            );
        }
        self.dims = dims;
        Ok(())
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Merge a batch: one linear pass over both sorted runs. On a shared
    /// coordinate the batch value replaces the stored one.
    pub fn merge(&mut self, batch: &ObservationBatch) -> Result<()> {
        if batch.dims() != self.dims {
            bail!(
                "observation batch dims {:?} do not match the stream dims {:?}",
                batch.dims(),
                self.dims
            );
        }
        let new = batch.entries();
        if new.is_empty() {
            return Ok(());
        }
        let old = std::mem::take(&mut self.entries);
        let mut out = Vec::with_capacity(old.len() + new.len());
        let key = |e: &(u32, u32, u32, f64)| (e.2, e.1, e.0);
        let (mut a, mut b) = (0usize, 0usize);
        while a < old.len() && b < new.len() {
            match key(&old[a]).cmp(&key(&new[b])) {
                std::cmp::Ordering::Less => {
                    out.push(old[a]);
                    a += 1;
                }
                std::cmp::Ordering::Greater => {
                    out.push(new[b]);
                    b += 1;
                }
                std::cmp::Ordering::Equal => {
                    // Revisit: the new observation replaces the old value.
                    out.push(new[b]);
                    a += 1;
                    b += 1;
                }
            }
        }
        out.extend_from_slice(&old[a..]);
        out.extend_from_slice(&new[b..]);
        self.entries = out;
        Ok(())
    }

    /// Materialise the observed support as a COO tensor for the masked
    /// kernels. Exact-zero observations are nudged to a subnormal-scale
    /// value so the sparse backends (whose `push` drops exact zeros —
    /// zero means *absent* there) keep the cell in the mask; the
    /// perturbation is below any fit tolerance.
    pub fn to_coo(&self) -> CooTensor {
        let mut t =
            CooTensor::with_capacity(self.dims.0, self.dims.1, self.dims.2, self.entries.len());
        for &(i, j, k, v) in &self.entries {
            let v = if v == 0.0 { f64::MIN_POSITIVE } else { v };
            t.push(i as usize, j as usize, k as usize, v);
        }
        t
    }

    /// The accumulated entries, sorted by `(k, j, i)`, unique coordinates.
    pub fn entries(&self) -> &[(u32, u32, u32, f64)] {
        &self.entries
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_config_is_off_and_valid() {
        let cfg = CompletionConfig::default();
        assert!(!cfg.enabled);
        cfg.validate().unwrap();
        assert!(CompletionConfig::enabled().enabled);
        CompletionConfig::enabled().validate().unwrap();
    }

    #[test]
    fn config_validation_rejects_bad_knobs() {
        let mut cfg = CompletionConfig::enabled();
        cfg.sweeps = 0;
        assert!(cfg.validate().is_err());
        let mut cfg = CompletionConfig::enabled();
        cfg.ridge = -1.0;
        assert!(cfg.validate().is_err());
        cfg.ridge = f64::NAN;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn batch_validates_ranges_and_values() {
        let mut b = ObservationBatch::new((2, 3, 4));
        b.push(1, 2, 3, 5.0).unwrap();
        assert!(b.push(2, 0, 0, 1.0).is_err(), "i out of range");
        assert!(b.push(0, 3, 0, 1.0).is_err(), "j out of range");
        assert!(b.push(0, 0, 4, 1.0).is_err(), "k out of range");
        assert!(b.push(0, 0, 0, f64::NAN).is_err(), "non-finite value");
        assert!(ObservationBatch::from_entries((2, 2, 2), vec![(0, 0, 2, 1.0)]).is_err());
    }

    #[test]
    fn coalesce_is_last_write_wins_and_order_independent() {
        // The same duplicate cell pushed in two different interleavings
        // must resolve to the same batch: latest push wins.
        let mut a = ObservationBatch::new((3, 3, 3));
        a.push(1, 1, 1, 1.0).unwrap();
        a.push(0, 2, 2, 7.0).unwrap();
        a.push(1, 1, 1, 2.0).unwrap();
        a.push(1, 1, 1, 3.0).unwrap();
        a.coalesce();
        assert_eq!(a.len(), 2);
        let got: Vec<_> = a.iter().collect();
        assert!(got.contains(&(1, 1, 1, 3.0)), "{got:?}");
        assert!(got.contains(&(0, 2, 2, 7.0)));
        // Zero observations survive coalescing — observed-as-zero is data.
        let z = ObservationBatch::from_entries((2, 2, 2), vec![(0, 0, 0, 0.0)]).unwrap();
        assert_eq!(z.len(), 1);
    }

    #[test]
    fn set_merges_with_revisit_overwrite() {
        let mut set = ObservationSet::new((4, 4, 4));
        let b1 = ObservationBatch::from_entries(
            (4, 4, 4),
            vec![(0, 0, 0, 1.0), (1, 2, 3, 4.0), (2, 2, 2, -1.0)],
        )
        .unwrap();
        set.merge(&b1).unwrap();
        assert_eq!(set.len(), 3);
        // Revisit (1,2,3) with a new value, add one fresh cell.
        let b2 = ObservationBatch::from_entries((4, 4, 4), vec![(1, 2, 3, 9.0), (3, 3, 3, 2.0)])
            .unwrap();
        set.merge(&b2).unwrap();
        assert_eq!(set.len(), 4, "revisit must overwrite, not duplicate");
        let v = set
            .entries()
            .iter()
            .find(|e| (e.0, e.1, e.2) == (1, 2, 3))
            .unwrap()
            .3;
        assert_eq!(v, 9.0);
        // Dim mismatch is rejected.
        let bad = ObservationBatch::new((5, 4, 4));
        assert!(set.merge(&bad).is_err());
    }

    #[test]
    fn to_coo_keeps_zero_observations_in_the_mask() {
        let mut set = ObservationSet::new((2, 2, 2));
        let b = ObservationBatch::from_entries((2, 2, 2), vec![(0, 0, 0, 0.0), (1, 1, 1, 3.0)])
            .unwrap();
        set.merge(&b).unwrap();
        let coo = set.to_coo();
        assert_eq!(coo.nnz(), 2, "an observed zero must stay in the support");
    }
}
