//! Dense third-order tensor, column-major within frontal slices
//! (`idx = i + I·j + I·J·k`, the Matlab/Tensor-Toolbox layout the paper's
//! artifact uses): frontal slice `X(:,:,k)` is one contiguous `I×J` block,
//! which both the dense MTTKRP and the PJRT hand-off exploit.

use super::{mode_dim, Tensor3};
use crate::linalg::Matrix;
use crate::util::Rng;

#[derive(Clone)]
pub struct DenseTensor {
    i: usize,
    j: usize,
    k: usize,
    data: Vec<f64>,
}

impl std::fmt::Debug for DenseTensor {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "DenseTensor({}x{}x{}, norm={:.4})", self.i, self.j, self.k, self.norm())
    }
}

impl DenseTensor {
    pub fn zeros(i: usize, j: usize, k: usize) -> Self {
        DenseTensor { i, j, k, data: vec![0.0; i * j * k] }
    }

    /// I.i.d. uniform entries — test/datagen helper.
    pub fn rand(i: usize, j: usize, k: usize, rng: &mut Rng) -> Self {
        let data = (0..i * j * k).map(|_| rng.uniform()).collect();
        DenseTensor { i, j, k, data }
    }

    pub fn from_vec(i: usize, j: usize, k: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), i * j * k);
        DenseTensor { i, j, k, data }
    }

    #[inline]
    fn idx(&self, i: usize, j: usize, k: usize) -> usize {
        debug_assert!(i < self.i && j < self.j && k < self.k);
        i + self.i * (j + self.j * k)
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize, k: usize) -> f64 {
        self.data[self.idx(i, j, k)]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, k: usize, v: f64) {
        let ix = self.idx(i, j, k);
        self.data[ix] = v;
    }

    #[inline]
    pub fn add_at(&mut self, i: usize, j: usize, k: usize, v: f64) {
        let ix = self.idx(i, j, k);
        self.data[ix] += v;
    }

    pub fn data(&self) -> &[f64] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// Contiguous frontal slice `X(:,:,k)` (column-major `I×J`).
    pub fn frontal_slice(&self, k: usize) -> &[f64] {
        let sz = self.i * self.j;
        &self.data[k * sz..(k + 1) * sz]
    }

    /// Mode-`n` unfolding, Kolda convention: `X_(1)` is `I × JK` with column
    /// `j + J·k`; `X_(2)` is `J × IK` with column `i + I·k`; `X_(3)` is
    /// `K × IJ` with column `i + I·j`.
    pub fn unfold(&self, mode: usize) -> Matrix {
        let (ni, nj, nk) = (self.i, self.j, self.k);
        match mode {
            0 => Matrix::from_fn(ni, nj * nk, |i, c| self.get(i, c % nj, c / nj)),
            1 => Matrix::from_fn(nj, ni * nk, |j, c| self.get(c % ni, j, c / ni)),
            2 => Matrix::from_fn(nk, ni * nj, |k, c| self.get(c % ni, c / ni, k)),
            _ => panic!("mode {mode} out of range"),
        }
    }

    /// Extract sub-tensor at given index lists (any order, with the output
    /// axes following the list order) — the sampling primitive.
    pub fn extract(&self, is: &[usize], js: &[usize], ks: &[usize]) -> DenseTensor {
        let mut out = DenseTensor::zeros(is.len(), js.len(), ks.len());
        for (kk, &k) in ks.iter().enumerate() {
            for (jj, &j) in js.iter().enumerate() {
                for (ii, &i) in is.iter().enumerate() {
                    out.set(ii, jj, kk, self.get(i, j, k));
                }
            }
        }
        out
    }

    /// Split along mode 3 at `at`: returns `(X[..,..,0..at], X[..,..,at..])`.
    pub fn split_mode3(&self, at: usize) -> (DenseTensor, DenseTensor) {
        assert!(at <= self.k);
        let sz = self.i * self.j;
        let first = DenseTensor::from_vec(self.i, self.j, at, self.data[..at * sz].to_vec());
        let second =
            DenseTensor::from_vec(self.i, self.j, self.k - at, self.data[at * sz..].to_vec());
        (first, second)
    }

    /// Append `other` along mode 3 (slices concatenate because frontal
    /// slices are contiguous).
    pub fn append_mode3(&mut self, other: &DenseTensor) {
        assert_eq!((self.i, self.j), (other.i, other.j), "mode-1/2 dims must match");
        self.data.extend_from_slice(&other.data);
        self.k += other.k;
    }

    /// Squared Frobenius norm.
    pub fn norm_sq(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum()
    }

    /// Tensor-times-matrix along `mode`: `Y = X ×_n M` with `M` of shape
    /// `new_dim × dim_n`. Used by CORCONDIA (`G = X ×₁ Ã⁺ ×₂ B⁺ ×₃ C⁺`).
    pub fn ttm(&self, mode: usize, m: &Matrix) -> DenseTensor {
        let (ni, nj, nk) = self.dims();
        let p = m.rows();
        match mode {
            0 => {
                assert_eq!(m.cols(), ni, "ttm mode-1 dim mismatch");
                let mut out = DenseTensor::zeros(p, nj, nk);
                for k in 0..nk {
                    for j in 0..nj {
                        for q in 0..p {
                            let mut acc = 0.0;
                            for i in 0..ni {
                                acc += m[(q, i)] * self.get(i, j, k);
                            }
                            out.set(q, j, k, acc);
                        }
                    }
                }
                out
            }
            1 => {
                assert_eq!(m.cols(), nj, "ttm mode-2 dim mismatch");
                let mut out = DenseTensor::zeros(ni, p, nk);
                for k in 0..nk {
                    for q in 0..p {
                        for i in 0..ni {
                            let mut acc = 0.0;
                            for j in 0..nj {
                                acc += m[(q, j)] * self.get(i, j, k);
                            }
                            out.set(i, q, k, acc);
                        }
                    }
                }
                out
            }
            2 => {
                assert_eq!(m.cols(), nk, "ttm mode-3 dim mismatch");
                let mut out = DenseTensor::zeros(ni, nj, p);
                for q in 0..p {
                    for k in 0..nk {
                        let c = m[(q, k)];
                        if c == 0.0 {
                            continue;
                        }
                        for j in 0..nj {
                            for i in 0..ni {
                                out.add_at(i, j, q, c * self.get(i, j, k));
                            }
                        }
                    }
                }
                out
            }
            _ => panic!("mode {mode} out of range"),
        }
    }
}

impl DenseTensor {
    /// Monomorphised MTTKRP hot loops: with `R` a compile-time constant the
    /// per-entry `t` loops become straight-line vector code (measured ~1.5-2×
    /// over the runtime-`r` fallback — EXPERIMENTS.md §Perf).
    fn mttkrp_const<const R: usize>(
        &self,
        mode: usize,
        a: &Matrix,
        b: &Matrix,
        c: &Matrix,
        out: &mut Matrix,
    ) {
        let (ni, nj, nk) = (self.i, self.j, self.k);
        match mode {
            0 => {
                let mut bc = [0.0f64; R];
                for k in 0..nk {
                    let slice = self.frontal_slice(k);
                    let c_row = c.row(k);
                    for j in 0..nj {
                        let b_row = b.row(j);
                        for t in 0..R {
                            bc[t] = b_row[t] * c_row[t];
                        }
                        let col = &slice[j * ni..(j + 1) * ni];
                        for (i, &x) in col.iter().enumerate() {
                            if x == 0.0 {
                                continue;
                            }
                            let o = out.row_mut(i);
                            for t in 0..R {
                                o[t] += x * bc[t];
                            }
                        }
                    }
                }
            }
            1 => {
                for k in 0..nk {
                    let slice = self.frontal_slice(k);
                    let c_row = c.row(k);
                    let mut cr = [0.0f64; R];
                    cr.copy_from_slice(&c_row[..R]);
                    for j in 0..nj {
                        let col = &slice[j * ni..(j + 1) * ni];
                        let mut acc = [0.0f64; R];
                        for (i, &x) in col.iter().enumerate() {
                            if x == 0.0 {
                                continue;
                            }
                            let a_row = a.row(i);
                            for t in 0..R {
                                acc[t] += x * a_row[t];
                            }
                        }
                        let o = out.row_mut(j);
                        for t in 0..R {
                            o[t] += acc[t] * cr[t];
                        }
                    }
                }
            }
            2 => {
                for k in 0..nk {
                    let slice = self.frontal_slice(k);
                    let mut acc = [0.0f64; R];
                    for j in 0..nj {
                        let b_row = b.row(j);
                        let col = &slice[j * ni..(j + 1) * ni];
                        let mut ja = [0.0f64; R];
                        for (i, &x) in col.iter().enumerate() {
                            if x == 0.0 {
                                continue;
                            }
                            let a_row = a.row(i);
                            for t in 0..R {
                                ja[t] += x * a_row[t];
                            }
                        }
                        for t in 0..R {
                            acc[t] += ja[t] * b_row[t];
                        }
                    }
                    let o = out.row_mut(k);
                    for t in 0..R {
                        o[t] += acc[t];
                    }
                }
            }
            _ => unreachable!(),
        }
    }
}

impl Tensor3 for DenseTensor {
    fn dims(&self) -> (usize, usize, usize) {
        (self.i, self.j, self.k)
    }

    fn norm(&self) -> f64 {
        self.norm_sq().sqrt()
    }

    fn nnz(&self) -> usize {
        self.data.iter().filter(|&&x| x != 0.0).count()
    }

    fn mttkrp_into(&self, mode: usize, a: &Matrix, b: &Matrix, c: &Matrix, out: &mut Matrix) {
        let r = match mode {
            0 => b.cols(),
            1 | 2 => a.cols(),
            _ => panic!("mode {mode} out of range"),
        };
        let (ni, nj, nk) = (self.i, self.j, self.k);
        assert_eq!(
            (out.rows(), out.cols()),
            (mode_dim(self.dims(), mode), r),
            "mttkrp_into out-buffer shape mismatch"
        );
        // Dirty-buffer contract: the kernels accumulate, so reset first.
        out.fill(0.0);
        // Monomorphised fast path for the common small ranks.
        match r {
            1 => return self.mttkrp_const::<1>(mode, a, b, c, out),
            2 => return self.mttkrp_const::<2>(mode, a, b, c, out),
            3 => return self.mttkrp_const::<3>(mode, a, b, c, out),
            4 => return self.mttkrp_const::<4>(mode, a, b, c, out),
            5 => return self.mttkrp_const::<5>(mode, a, b, c, out),
            6 => return self.mttkrp_const::<6>(mode, a, b, c, out),
            8 => return self.mttkrp_const::<8>(mode, a, b, c, out),
            10 => return self.mttkrp_const::<10>(mode, a, b, c, out),
            16 => return self.mttkrp_const::<16>(mode, a, b, c, out),
            _ => {}
        }
        match mode {
            0 => {
                // M[i,:] += X(i,j,k) * (B[j,:] .* C[k,:])
                assert_eq!(b.rows(), nj);
                assert_eq!(c.rows(), nk);
                let mut bc = vec![0.0; r];
                for k in 0..nk {
                    let slice = self.frontal_slice(k);
                    let c_row = c.row(k);
                    for j in 0..nj {
                        let b_row = b.row(j);
                        for t in 0..r {
                            bc[t] = b_row[t] * c_row[t];
                        }
                        let col = &slice[j * ni..(j + 1) * ni];
                        for (i, &x) in col.iter().enumerate() {
                            if x == 0.0 {
                                continue;
                            }
                            let o = out.row_mut(i);
                            for t in 0..r {
                                o[t] += x * bc[t];
                            }
                        }
                    }
                }
            }
            1 => {
                // M[j,:] += X(i,j,k) * (A[i,:] .* C[k,:])
                assert_eq!(a.rows(), ni);
                assert_eq!(c.rows(), nk);
                for k in 0..nk {
                    let slice = self.frontal_slice(k);
                    let c_row = c.row(k);
                    for j in 0..nj {
                        let col = &slice[j * ni..(j + 1) * ni];
                        let o = out.row_mut(j);
                        for (i, &x) in col.iter().enumerate() {
                            if x == 0.0 {
                                continue;
                            }
                            let a_row = a.row(i);
                            for t in 0..r {
                                o[t] += x * a_row[t] * c_row[t];
                            }
                        }
                    }
                }
            }
            2 => {
                // M[k,:] += X(i,j,k) * (A[i,:] .* B[j,:])
                assert_eq!(a.rows(), ni);
                assert_eq!(b.rows(), nj);
                for k in 0..nk {
                    let slice = self.frontal_slice(k);
                    let o = out.row_mut(k);
                    for j in 0..nj {
                        let b_row = b.row(j);
                        let col = &slice[j * ni..(j + 1) * ni];
                        for (i, &x) in col.iter().enumerate() {
                            if x == 0.0 {
                                continue;
                            }
                            let a_row = a.row(i);
                            for t in 0..r {
                                o[t] += x * a_row[t] * b_row[t];
                            }
                        }
                    }
                }
            }
            _ => unreachable!(),
        }
    }

    fn mode_sum_squares(&self, mode: usize) -> Vec<f64> {
        let mut out = vec![0.0; mode_dim(self.dims(), mode)];
        let (ni, nj, nk) = (self.i, self.j, self.k);
        for k in 0..nk {
            let slice = self.frontal_slice(k);
            for j in 0..nj {
                let col = &slice[j * ni..(j + 1) * ni];
                match mode {
                    0 => {
                        for (i, &x) in col.iter().enumerate() {
                            out[i] += x * x;
                        }
                    }
                    1 => {
                        out[j] += col.iter().map(|x| x * x).sum::<f64>();
                    }
                    2 => {
                        out[k] += col.iter().map(|x| x * x).sum::<f64>();
                    }
                    _ => unreachable!(),
                }
            }
        }
        out
    }

    fn inner_with_kruskal(&self, lambda: &[f64], a: &Matrix, b: &Matrix, c: &Matrix) -> f64 {
        // ⟨X, model⟩ = Σ_r λ_r Σ_ijk X(i,j,k) A(i,r)B(j,r)C(k,r)
        //            = Σ_r λ_r · ⟨MTTKRP_3(X; A,B)[k,r], C[k,r]⟩
        let m3 = self.mttkrp(2, a, b, c);
        let r = lambda.len();
        let mut acc = 0.0;
        for k in 0..c.rows() {
            let mr = m3.row(k);
            let cr = c.row(k);
            for t in 0..r {
                acc += lambda[t] * mr[t] * cr[t];
            }
        }
        acc
    }

    fn masked_normals_into(
        &self,
        mode: usize,
        a: &Matrix,
        b: &Matrix,
        c: &Matrix,
        rhs: &mut Matrix,
        grams: &mut Matrix,
    ) {
        let r = a.cols();
        super::masked_normals_prepare(self.dims(), mode, r, rhs, grams);
        // Dense storage has no notion of an absent cell: every entry —
        // zeros included — is observed, so each row's gram converges to
        // the shared normal matrix the fully-observed ALS step uses.
        let (ni, nj, nk) = self.dims();
        let mut w = vec![0.0f64; r];
        for k in 0..nk {
            for j in 0..nj {
                for i in 0..ni {
                    let (dst, f1, f2) = match mode {
                        0 => (i, b.row(j), c.row(k)),
                        1 => (j, a.row(i), c.row(k)),
                        2 => (k, a.row(i), b.row(j)),
                        _ => panic!("mode {mode} out of range"),
                    };
                    for t in 0..r {
                        w[t] = f1[t] * f2[t];
                    }
                    super::masked_normals_accumulate(rhs, grams, dst, self.get(i, j, k), &w);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> DenseTensor {
        // 2x3x2 with distinct entries.
        let mut t = DenseTensor::zeros(2, 3, 2);
        let mut v = 1.0;
        for k in 0..2 {
            for j in 0..3 {
                for i in 0..2 {
                    t.set(i, j, k, v);
                    v += 1.0;
                }
            }
        }
        t
    }

    #[test]
    fn get_set_roundtrip() {
        let mut t = DenseTensor::zeros(3, 4, 5);
        t.set(2, 3, 4, 7.5);
        assert_eq!(t.get(2, 3, 4), 7.5);
        assert_eq!(t.nnz(), 1);
    }

    #[test]
    fn frontal_slice_layout() {
        let t = small();
        // slice k=1 starts after 6 entries
        assert_eq!(t.frontal_slice(1)[0], 7.0);
        assert_eq!(t.get(0, 0, 1), 7.0);
    }

    #[test]
    fn unfold_shapes_and_values() {
        let t = small();
        let u1 = t.unfold(0);
        assert_eq!((u1.rows(), u1.cols()), (2, 6));
        // X(1)[i, j + J*k]
        assert_eq!(u1[(0, 1)], t.get(0, 1, 0));
        assert_eq!(u1[(1, 3 + 2)], t.get(1, 2, 1));
        let u2 = t.unfold(1);
        assert_eq!((u2.rows(), u2.cols()), (3, 4));
        assert_eq!(u2[(2, 1 + 2)], t.get(1, 2, 1));
        let u3 = t.unfold(2);
        assert_eq!((u3.rows(), u3.cols()), (2, 6));
        assert_eq!(u3[(1, 0)], t.get(0, 0, 1));
    }

    /// MTTKRP must equal the definitional `X_(n) · KRP` computed explicitly.
    #[test]
    fn mttkrp_matches_definition() {
        let mut rng = Rng::new(10);
        let t = DenseTensor::rand(4, 5, 6, &mut rng);
        let a = Matrix::rand_gaussian(4, 3, &mut rng);
        let b = Matrix::rand_gaussian(5, 3, &mut rng);
        let c = Matrix::rand_gaussian(6, 3, &mut rng);
        // Kolda: X(1)(C ⊙ B); column (j + J*k) pairs with KR row (k*J + j) = C(k,:).*B(j,:)
        let expect0 = t.unfold(0).matmul(&c.khatri_rao(&b));
        assert!(t.mttkrp(0, &a, &b, &c).max_abs_diff(&expect0) < 1e-10);
        let expect1 = t.unfold(1).matmul(&c.khatri_rao(&a));
        assert!(t.mttkrp(1, &a, &b, &c).max_abs_diff(&expect1) < 1e-10);
        let expect2 = t.unfold(2).matmul(&b.khatri_rao(&a));
        assert!(t.mttkrp(2, &a, &b, &c).max_abs_diff(&expect2) < 1e-10);
    }

    #[test]
    fn mode_sum_squares_matches_manual() {
        let t = small();
        for mode in 0..3 {
            let got = t.mode_sum_squares(mode);
            let (ni, nj, nk) = t.dims();
            let dim = [ni, nj, nk][mode];
            let mut expect = vec![0.0; dim];
            for i in 0..ni {
                for j in 0..nj {
                    for k in 0..nk {
                        let v = t.get(i, j, k);
                        expect[[i, j, k][mode]] += v * v;
                    }
                }
            }
            for (g, e) in got.iter().zip(&expect) {
                assert!((g - e).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn extract_orders_axes_by_list() {
        let t = small();
        let s = t.extract(&[1], &[2, 0], &[1]);
        assert_eq!(s.dims(), (1, 2, 1));
        assert_eq!(s.get(0, 0, 0), t.get(1, 2, 1));
        assert_eq!(s.get(0, 1, 0), t.get(1, 0, 1));
    }

    #[test]
    fn split_append_roundtrip() {
        let mut rng = Rng::new(3);
        let t = DenseTensor::rand(3, 4, 7, &mut rng);
        let (mut a, b) = t.split_mode3(3);
        assert_eq!(a.dims(), (3, 4, 3));
        assert_eq!(b.dims(), (3, 4, 4));
        a.append_mode3(&b);
        assert_eq!(a.dims(), t.dims());
        assert_eq!(a.data(), t.data());
    }

    #[test]
    fn norm_matches_data() {
        let t = small();
        let expect: f64 = (1..=12).map(|v| (v * v) as f64).sum::<f64>();
        assert!((t.norm() - expect.sqrt()).abs() < 1e-12);
    }

    #[test]
    fn inner_with_kruskal_matches_reconstruction() {
        let mut rng = Rng::new(4);
        let t = DenseTensor::rand(3, 4, 5, &mut rng);
        let a = Matrix::rand_gaussian(3, 2, &mut rng);
        let b = Matrix::rand_gaussian(4, 2, &mut rng);
        let c = Matrix::rand_gaussian(5, 2, &mut rng);
        let lam = vec![0.7, 1.3];
        let mut expect = 0.0;
        for i in 0..3 {
            for j in 0..4 {
                for k in 0..5 {
                    let mut m = 0.0;
                    for r in 0..2 {
                        m += lam[r] * a[(i, r)] * b[(j, r)] * c[(k, r)];
                    }
                    expect += t.get(i, j, k) * m;
                }
            }
        }
        let got = t.inner_with_kruskal(&lam, &a, &b, &c);
        assert!((got - expect).abs() < 1e-9, "{got} vs {expect}");
    }

    #[test]
    #[should_panic]
    fn unfold_bad_mode_panics() {
        let t = small();
        let _ = t.unfold(3);
    }
}
