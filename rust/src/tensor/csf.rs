//! Compressed Sparse Fiber (CSF) tensor backend.
//!
//! The COO backend walks a flat entry list, which makes MTTKRP — the
//! dominant cost inside every sample ALS sweep — pay per *entry* for work
//! that is shared per *fiber* and per *root slice*: re-loading factor rows,
//! scattering into the output, and (in the parallel path) allocating,
//! zeroing and reducing full-size per-thread accumulators. CSF stores one
//! mode-rooted fiber tree per mode (built once, by sorting), so MTTKRP for
//! mode `n` walks orientation `n`:
//!
//! ```text
//! root r (output row)            — accumulated in registers, stored once
//! └── fiber (r, m)               — one mid-factor row load per fiber
//!     └── leaf entries (l, v)    — v · leaf_factor[l, :], contiguous
//! ```
//!
//! Parallelism: root ranges are disjoint output rows, so workers write
//! range-local scratch with **no contention and no reduction pass** —
//! unlike the COO path, which must merge full `out_dim × R` partials.
//! Ranges are balanced by entry count (heavy-tailed real data concentrates
//! nnz on few roots).
//!
//! Memory: each orientation owns its values in its own order (3× the COO
//! value payload). That trade is deliberate — the accumulated tensor is
//! read by `3 · iters · reps` MTTKRPs per ingest and rebuilt once.

use super::sparse::inverse_map;
use super::{mode_dim, CooTensor, DenseTensor, Tensor3};
use crate::linalg::Matrix;
use crate::util::par::workers_for;
use crate::util::parallel_map;

/// One mode-rooted fiber tree. All pointer arrays are `u32` (nnz beyond 4B
/// entries is out of scope for this testbed, as in the COO backend).
#[derive(Clone, Default)]
struct Orientation {
    /// Distinct root indices, ascending.
    roots: Vec<u32>,
    /// Fibers of root `f` are `fiber_ptr[f]..fiber_ptr[f+1]` (into `mids`).
    fiber_ptr: Vec<u32>,
    /// Mid-level index per fiber.
    mids: Vec<u32>,
    /// Entries of fiber `g` are `entry_ptr[g]..entry_ptr[g+1]`.
    entry_ptr: Vec<u32>,
    /// Leaf-level index per entry, fiber-contiguous.
    leaves: Vec<u32>,
    /// Value per entry, in this orientation's order.
    vals: Vec<f64>,
}

impl Orientation {
    /// Entry range (into `leaves`/`vals`) owned by root `f` — contiguous
    /// because fibers and entries are laid out in root-major order.
    #[inline]
    fn root_entries(&self, f: usize) -> std::ops::Range<usize> {
        let e0 = self.entry_ptr[self.fiber_ptr[f] as usize] as usize;
        let e1 = self.entry_ptr[self.fiber_ptr[f + 1] as usize] as usize;
        e0..e1
    }
}

/// Build the orientation whose root level is `mode`. `(root, mid, leaf)`
/// per mode: 0 → (i, j, k), 1 → (j, i, k), 2 → (k, j, i) — the leaf/mid
/// assignment pairs each orientation with the factor matrices its MTTKRP
/// needs (`mode 0: Σ_j B[j] ∘ Σ_k v·C[k]`, etc.).
fn build_orientation(ii: &[u32], jj: &[u32], kk: &[u32], vv: &[f64], mode: usize) -> Orientation {
    let (rs, ms, ls): (&[u32], &[u32], &[u32]) = match mode {
        0 => (ii, jj, kk),
        1 => (jj, ii, kk),
        2 => (kk, jj, ii),
        _ => panic!("mode {mode} out of range for a 3-mode tensor"),
    };
    let n = vv.len();
    let mut order: Vec<u32> = (0..n as u32).collect();
    order.sort_unstable_by_key(|&e| {
        let e = e as usize;
        (rs[e], ms[e], ls[e])
    });
    let mut o = Orientation {
        leaves: Vec::with_capacity(n),
        vals: Vec::with_capacity(n),
        ..Orientation::default()
    };
    for &e in &order {
        let e = e as usize;
        let (root, mid, leaf, v) = (rs[e], ms[e], ls[e], vv[e]);
        let new_root = o.roots.last() != Some(&root);
        if new_root {
            o.roots.push(root);
            o.fiber_ptr.push(o.mids.len() as u32);
        }
        if new_root || o.mids.last() != Some(&mid) {
            o.mids.push(mid);
            o.entry_ptr.push(o.leaves.len() as u32);
        }
        o.leaves.push(leaf);
        o.vals.push(v);
    }
    o.fiber_ptr.push(o.mids.len() as u32);
    o.entry_ptr.push(o.leaves.len() as u32);
    o
}

/// CSF sparse tensor: three mode-rooted fiber trees over one coalesced
/// entry set. Immutable once built (mode-3 growth rebuilds — see
/// [`CsfTensor::append_mode3`]).
#[derive(Clone)]
pub struct CsfTensor {
    dims: (usize, usize, usize),
    nnz: usize,
    orient: [Orientation; 3],
}

impl std::fmt::Debug for CsfTensor {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "CsfTensor({}x{}x{}, nnz={}, roots={}/{}/{})",
            self.dims.0,
            self.dims.1,
            self.dims.2,
            self.nnz,
            self.orient[0].roots.len(),
            self.orient[1].roots.len(),
            self.orient[2].roots.len()
        )
    }
}

impl CsfTensor {
    /// Build from COO. Coalesces first (CSF requires unique coordinates;
    /// duplicates sum, exact zeros drop — standard COO semantics).
    pub fn from_coo(mut coo: CooTensor) -> Self {
        coo.coalesce();
        let dims = coo.dims();
        let n = coo.nnz();
        let mut ii = Vec::with_capacity(n);
        let mut jj = Vec::with_capacity(n);
        let mut kk = Vec::with_capacity(n);
        let mut vv = Vec::with_capacity(n);
        for (i, j, k, v) in coo.iter() {
            ii.push(i as u32);
            jj.push(j as u32);
            kk.push(k as u32);
            vv.push(v);
        }
        CsfTensor {
            dims,
            nnz: n,
            orient: [
                build_orientation(&ii, &jj, &kk, &vv, 0),
                build_orientation(&ii, &jj, &kk, &vv, 1),
                build_orientation(&ii, &jj, &kk, &vv, 2),
            ],
        }
    }

    pub fn from_dense(d: &DenseTensor, threshold: f64) -> Self {
        Self::from_coo(CooTensor::from_dense(d, threshold))
    }

    /// Entry iterator `(i, j, k, v)` in `(i, j, k)`-sorted order.
    pub fn iter(&self) -> impl Iterator<Item = (usize, usize, usize, f64)> + '_ {
        let o = &self.orient[0];
        (0..o.roots.len()).flat_map(move |f| {
            let i = o.roots[f] as usize;
            (o.fiber_ptr[f] as usize..o.fiber_ptr[f + 1] as usize).flat_map(move |g| {
                let j = o.mids[g] as usize;
                (o.entry_ptr[g] as usize..o.entry_ptr[g + 1] as usize)
                    .map(move |e| (i, j, o.leaves[e] as usize, o.vals[e]))
            })
        })
    }

    pub fn to_coo(&self) -> CooTensor {
        let mut out =
            CooTensor::with_capacity(self.dims.0, self.dims.1, self.dims.2, self.nnz);
        for (i, j, k, v) in self.iter() {
            out.push(i, j, k, v);
        }
        out
    }

    pub fn to_dense(&self) -> DenseTensor {
        let (ni, nj, nk) = self.dims;
        let mut d = DenseTensor::zeros(ni, nj, nk);
        for (i, j, k, v) in self.iter() {
            d.add_at(i, j, k, v);
        }
        d
    }

    /// Extract the sub-tensor at the given index lists by walking the
    /// mode-1 fiber tree: a root absent from `is` skips its whole subtree
    /// and a fiber absent from `js` skips all its leaves — the win over the
    /// COO scan, which tests every nonzero against all three maps. This
    /// runs `r` times per ingest (once per sampling repetition).
    pub fn extract(&self, is: &[usize], js: &[usize], ks: &[usize]) -> CooTensor {
        let inv_i = inverse_map(self.dims.0, is);
        let inv_j = inverse_map(self.dims.1, js);
        let inv_k = inverse_map(self.dims.2, ks);
        let o = &self.orient[0];
        let mut out = CooTensor::new(is.len(), js.len(), ks.len());
        for f in 0..o.roots.len() {
            let Some(ni) = inv_i[o.roots[f] as usize] else {
                continue;
            };
            for g in o.fiber_ptr[f] as usize..o.fiber_ptr[f + 1] as usize {
                let Some(nj) = inv_j[o.mids[g] as usize] else {
                    continue;
                };
                for e in o.entry_ptr[g] as usize..o.entry_ptr[g + 1] as usize {
                    let Some(nk) = inv_k[o.leaves[e] as usize] else {
                        continue;
                    };
                    out.push(ni as usize, nj as usize, nk as usize, o.vals[e]);
                }
            }
        }
        out
    }

    /// Entries of frontal slice `k` as `(i, j, v)` triples, straight off
    /// the mode-3 tree (root = k) — the streaming replay primitive.
    pub fn slice_entries(&self, k: usize) -> Vec<(u32, u32, f64)> {
        let o = &self.orient[2];
        let Ok(f) = o.roots.binary_search(&(k as u32)) else {
            return Vec::new();
        };
        let mut out = Vec::new();
        for g in o.fiber_ptr[f] as usize..o.fiber_ptr[f + 1] as usize {
            let j = o.mids[g];
            for e in o.entry_ptr[g] as usize..o.entry_ptr[g + 1] as usize {
                // Orientation 2 is (root k, mid j, leaf i).
                out.push((o.leaves[e], j, o.vals[e]));
            }
        }
        out
    }

    /// Append `other` along mode 3. The fiber trees are positional indexes,
    /// so growth is a rebuild: `O(nnz log nnz)` — about one MTTKRP sweep of
    /// work, paid once per ingest vs the `3 · iters · reps` MTTKRPs that
    /// read the result.
    pub fn append_mode3(&mut self, other: &CooTensor) {
        let mut coo = self.to_coo();
        coo.append_mode3(other);
        *self = CsfTensor::from_coo(coo);
    }

    /// Split along mode 3 at `at` (COO out: splits are transient stream
    /// plumbing, promotion re-applies where it pays).
    pub fn split_mode3(&self, at: usize) -> (CooTensor, CooTensor) {
        self.to_coo().split_mode3(at)
    }

    pub fn norm_sq(&self) -> f64 {
        self.orient[0].vals.iter().map(|v| v * v).sum()
    }

    /// Density in `[0, 1]`.
    pub fn density(&self) -> f64 {
        let total = self.dims.0 * self.dims.1 * self.dims.2;
        if total == 0 {
            0.0
        } else {
            self.nnz as f64 / total as f64
        }
    }
}

/// Contiguous root ranges with near-equal *entry* counts (roots are a poor
/// balance unit on heavy-tailed data where a few slices hold most nonzeros).
fn balanced_root_ranges(o: &Orientation, parts: usize) -> Vec<std::ops::Range<usize>> {
    let nroots = o.roots.len();
    if parts <= 1 || nroots <= 1 {
        return vec![0..nroots];
    }
    let per = o.vals.len().div_ceil(parts).max(1);
    let mut out = Vec::with_capacity(parts);
    let mut start = 0;
    let mut acc = 0;
    for f in 0..nroots {
        acc += o.root_entries(f).len();
        if acc >= per && f + 1 < nroots {
            out.push(start..f + 1);
            start = f + 1;
            acc = 0;
        }
    }
    out.push(start..nroots);
    out
}

/// Fiber-tree MTTKRP over a root range, compile-time rank: the output row
/// accumulates in registers and stores once per root; each fiber loads its
/// mid-factor row once; leaf entries stream contiguously.
fn mttkrp_roots_const<const R: usize>(
    o: &Orientation,
    midf: &Matrix,
    leaff: &Matrix,
    range: std::ops::Range<usize>,
    local: &mut Matrix,
) {
    for (row, f) in range.enumerate() {
        let mut acc = [0.0f64; R];
        for g in o.fiber_ptr[f] as usize..o.fiber_ptr[f + 1] as usize {
            let mut fib = [0.0f64; R];
            let es = o.entry_ptr[g] as usize..o.entry_ptr[g + 1] as usize;
            for (leaf, v) in o.leaves[es.clone()].iter().zip(&o.vals[es]) {
                let lrow = leaff.row(*leaf as usize);
                for t in 0..R {
                    fib[t] += v * lrow[t];
                }
            }
            let mrow = midf.row(o.mids[g] as usize);
            for t in 0..R {
                acc[t] += fib[t] * mrow[t];
            }
        }
        local.row_mut(row)[..R].copy_from_slice(&acc);
    }
}

/// Runtime-rank fallback of [`mttkrp_roots_const`].
fn mttkrp_roots_generic(
    o: &Orientation,
    midf: &Matrix,
    leaff: &Matrix,
    range: std::ops::Range<usize>,
    local: &mut Matrix,
) {
    let r = midf.cols();
    let mut fib = vec![0.0f64; r];
    for (row, f) in range.enumerate() {
        let out = local.row_mut(row);
        for g in o.fiber_ptr[f] as usize..o.fiber_ptr[f + 1] as usize {
            fib.iter_mut().for_each(|x| *x = 0.0);
            let es = o.entry_ptr[g] as usize..o.entry_ptr[g + 1] as usize;
            for (leaf, v) in o.leaves[es.clone()].iter().zip(&o.vals[es]) {
                let lrow = leaff.row(*leaf as usize);
                for t in 0..r {
                    fib[t] += v * lrow[t];
                }
            }
            let mrow = midf.row(o.mids[g] as usize);
            for t in 0..r {
                out[t] += fib[t] * mrow[t];
            }
        }
    }
}

impl Tensor3 for CsfTensor {
    fn dims(&self) -> (usize, usize, usize) {
        self.dims
    }

    fn norm(&self) -> f64 {
        self.norm_sq().sqrt()
    }

    fn nnz(&self) -> usize {
        self.nnz
    }

    fn mttkrp(&self, mode: usize, a: &Matrix, b: &Matrix, c: &Matrix) -> Matrix {
        let r = a.cols();
        debug_assert_eq!(b.cols(), r);
        debug_assert_eq!(c.cols(), r);
        // Mid/leaf factors per orientation — see `build_orientation`.
        let (midf, leaff) = match mode {
            0 => (b, c),
            1 => (a, c),
            2 => (b, a),
            _ => panic!("mode {mode} out of range"),
        };
        let o = &self.orient[mode];
        let mut out = Matrix::zeros(mode_dim(self.dims, mode), r);
        if o.roots.is_empty() {
            return out;
        }
        let nw = workers_for(self.nnz / 4096 + 1).min(o.roots.len());
        let ranges = balanced_root_ranges(o, nw);
        let locals = parallel_map(&ranges, |_, range| {
            let mut local = Matrix::zeros(range.len(), r);
            match r {
                1 => mttkrp_roots_const::<1>(o, midf, leaff, range.clone(), &mut local),
                2 => mttkrp_roots_const::<2>(o, midf, leaff, range.clone(), &mut local),
                3 => mttkrp_roots_const::<3>(o, midf, leaff, range.clone(), &mut local),
                4 => mttkrp_roots_const::<4>(o, midf, leaff, range.clone(), &mut local),
                5 => mttkrp_roots_const::<5>(o, midf, leaff, range.clone(), &mut local),
                6 => mttkrp_roots_const::<6>(o, midf, leaff, range.clone(), &mut local),
                8 => mttkrp_roots_const::<8>(o, midf, leaff, range.clone(), &mut local),
                10 => mttkrp_roots_const::<10>(o, midf, leaff, range.clone(), &mut local),
                16 => mttkrp_roots_const::<16>(o, midf, leaff, range.clone(), &mut local),
                _ => mttkrp_roots_generic(o, midf, leaff, range.clone(), &mut local),
            }
            local
        });
        // Scatter range-local rows to their (disjoint) global root rows.
        for (range, local) in ranges.iter().zip(&locals) {
            for (row, f) in range.clone().enumerate() {
                out.row_mut(o.roots[f] as usize).copy_from_slice(local.row(row));
            }
        }
        out
    }

    fn mode_sum_squares(&self, mode: usize) -> Vec<f64> {
        let o = &self.orient[mode];
        let mut out = vec![0.0; mode_dim(self.dims, mode)];
        for f in 0..o.roots.len() {
            out[o.roots[f] as usize] =
                o.vals[o.root_entries(f)].iter().map(|v| v * v).sum();
        }
        out
    }

    fn inner_with_kruskal(&self, lambda: &[f64], a: &Matrix, b: &Matrix, c: &Matrix) -> f64 {
        let r = lambda.len();
        let o = &self.orient[0];
        let mut acc = 0.0;
        let mut rootacc = vec![0.0f64; r];
        let mut fib = vec![0.0f64; r];
        for f in 0..o.roots.len() {
            rootacc.iter_mut().for_each(|x| *x = 0.0);
            for g in o.fiber_ptr[f] as usize..o.fiber_ptr[f + 1] as usize {
                fib.iter_mut().for_each(|x| *x = 0.0);
                let es = o.entry_ptr[g] as usize..o.entry_ptr[g + 1] as usize;
                for (leaf, v) in o.leaves[es.clone()].iter().zip(&o.vals[es]) {
                    let crow = c.row(*leaf as usize);
                    for t in 0..r {
                        fib[t] += v * crow[t];
                    }
                }
                let brow = b.row(o.mids[g] as usize);
                for t in 0..r {
                    rootacc[t] += fib[t] * brow[t];
                }
            }
            let arow = a.row(o.roots[f] as usize);
            for t in 0..r {
                acc += lambda[t] * arow[t] * rootacc[t];
            }
        }
        acc
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn roundtrips_coo_exactly() {
        let mut rng = Rng::new(1);
        let coo = CooTensor::rand(7, 6, 5, 0.3, &mut rng);
        let csf = CsfTensor::from_coo(coo.clone());
        assert_eq!(csf.nnz(), coo.nnz());
        assert!((csf.norm() - coo.norm()).abs() < 1e-12);
        let d1 = csf.to_coo().to_dense();
        let d2 = coo.to_dense();
        assert_eq!(d1.data(), d2.data());
        assert_eq!(csf.to_dense().data(), d2.data());
    }

    #[test]
    fn from_coo_coalesces_duplicates() {
        let mut coo = CooTensor::new(3, 3, 3);
        coo.push(1, 1, 1, 2.0);
        coo.push(1, 1, 1, 3.0);
        coo.push(0, 2, 2, 1.0);
        coo.push(0, 2, 2, -1.0); // cancels
        let csf = CsfTensor::from_coo(coo);
        assert_eq!(csf.nnz(), 1);
        assert_eq!(csf.iter().next().unwrap(), (1, 1, 1, 5.0));
    }

    #[test]
    fn mttkrp_matches_dense_all_modes() {
        let mut rng = Rng::new(2);
        for r in [1usize, 2, 3, 4, 7, 8, 16] {
            let coo = CooTensor::rand(9, 8, 7, 0.3, &mut rng);
            let dense = coo.to_dense();
            let csf = CsfTensor::from_coo(coo);
            let a = Matrix::rand_gaussian(9, r, &mut rng);
            let b = Matrix::rand_gaussian(8, r, &mut rng);
            let c = Matrix::rand_gaussian(7, r, &mut rng);
            for mode in 0..3 {
                let mc = csf.mttkrp(mode, &a, &b, &c);
                let md = dense.mttkrp(mode, &a, &b, &c);
                assert!(mc.max_abs_diff(&md) < 1e-10, "rank {r} mode {mode}");
            }
        }
    }

    #[test]
    fn mttkrp_parallel_ranges_cover_all_roots() {
        // Large enough nnz to force multiple worker ranges.
        let mut rng = Rng::new(3);
        let coo = CooTensor::rand(50, 40, 30, 0.4, &mut rng);
        assert!(coo.nnz() > 8192);
        let dense = coo.to_dense();
        let csf = CsfTensor::from_coo(coo);
        let a = Matrix::rand_gaussian(50, 4, &mut rng);
        let b = Matrix::rand_gaussian(40, 4, &mut rng);
        let c = Matrix::rand_gaussian(30, 4, &mut rng);
        for mode in 0..3 {
            let mc = csf.mttkrp(mode, &a, &b, &c);
            let md = dense.mttkrp(mode, &a, &b, &c);
            assert!(mc.max_abs_diff(&md) < 1e-9, "mode {mode}");
        }
    }

    #[test]
    fn mode_sum_squares_and_inner_match_dense() {
        let mut rng = Rng::new(4);
        let coo = CooTensor::rand(8, 7, 6, 0.4, &mut rng);
        let dense = coo.to_dense();
        let csf = CsfTensor::from_coo(coo);
        for mode in 0..3 {
            let sc = csf.mode_sum_squares(mode);
            let sd = dense.mode_sum_squares(mode);
            for (x, y) in sc.iter().zip(&sd) {
                assert!((x - y).abs() < 1e-12);
            }
        }
        let a = Matrix::rand_gaussian(8, 3, &mut rng);
        let b = Matrix::rand_gaussian(7, 3, &mut rng);
        let c = Matrix::rand_gaussian(6, 3, &mut rng);
        let lam = vec![1.2, 0.5, 2.0];
        let ic = csf.inner_with_kruskal(&lam, &a, &b, &c);
        let id = dense.inner_with_kruskal(&lam, &a, &b, &c);
        assert!((ic - id).abs() < 1e-9);
    }

    #[test]
    fn extract_matches_coo_extract() {
        let mut rng = Rng::new(5);
        let coo = CooTensor::rand(10, 9, 8, 0.35, &mut rng);
        let csf = CsfTensor::from_coo(coo.clone());
        let is = vec![0, 3, 7, 9];
        let js = vec![8, 1, 4];
        let ks = vec![2, 5];
        let dc = csf.extract(&is, &js, &ks).to_dense();
        let dd = coo.extract(&is, &js, &ks).to_dense();
        assert_eq!(dc.data(), dd.data());
    }

    #[test]
    fn slice_entries_match_iter_filter() {
        let mut rng = Rng::new(6);
        let coo = CooTensor::rand(6, 6, 6, 0.4, &mut rng);
        let csf = CsfTensor::from_coo(coo.clone());
        for k in 0..6 {
            let mut got: Vec<(usize, usize, f64)> = csf
                .slice_entries(k)
                .into_iter()
                .map(|(i, j, v)| (i as usize, j as usize, v))
                .collect();
            got.sort_by(|a, b| (a.0, a.1).cmp(&(b.0, b.1)));
            let mut want: Vec<(usize, usize, f64)> = csf
                .iter()
                .filter(|&(_, _, kk, _)| kk == k)
                .map(|(i, j, _, v)| (i, j, v))
                .collect();
            want.sort_by(|a, b| (a.0, a.1).cmp(&(b.0, b.1)));
            assert_eq!(got, want, "slice {k}");
        }
    }

    #[test]
    fn append_and_split_roundtrip() {
        let mut rng = Rng::new(7);
        let coo = CooTensor::rand(5, 5, 8, 0.4, &mut rng);
        let batch = CooTensor::rand(5, 5, 3, 0.4, &mut rng);
        let mut csf = CsfTensor::from_coo(coo.clone());
        csf.append_mode3(&batch);
        assert_eq!(csf.dims(), (5, 5, 11));
        let mut want = coo.clone();
        want.append_mode3(&batch);
        want.coalesce();
        assert_eq!(csf.to_dense().data(), want.to_dense().data());
        let (head, tail) = csf.split_mode3(8);
        let mut coalesced = coo;
        coalesced.coalesce();
        let want_head = coalesced.to_dense();
        assert_eq!(head.to_dense().data(), want_head.data());
        assert_eq!(tail.dims().2, 3);
    }

    #[test]
    fn empty_and_degenerate_safe() {
        let empty = CsfTensor::from_coo(CooTensor::new(4, 4, 4));
        assert_eq!(empty.nnz(), 0);
        assert_eq!(empty.norm(), 0.0);
        let a = Matrix::zeros(4, 2);
        for mode in 0..3 {
            assert_eq!(empty.mttkrp(mode, &a, &a, &a).frob_norm(), 0.0);
            assert_eq!(empty.mode_sum_squares(mode), vec![0.0; 4]);
        }
        assert_eq!(empty.inner_with_kruskal(&[1.0, 1.0], &a, &a, &a), 0.0);
        // Single fiber: all entries share (i, j).
        let mut coo = CooTensor::new(3, 3, 5);
        for k in 0..5 {
            coo.push(1, 2, k, (k + 1) as f64);
        }
        let csf = CsfTensor::from_coo(coo.clone());
        let dense = coo.to_dense();
        let mut rng = Rng::new(8);
        let fa = Matrix::rand_gaussian(3, 2, &mut rng);
        let fb = Matrix::rand_gaussian(3, 2, &mut rng);
        let fc = Matrix::rand_gaussian(5, 2, &mut rng);
        for mode in 0..3 {
            assert!(
                csf.mttkrp(mode, &fa, &fb, &fc)
                    .max_abs_diff(&dense.mttkrp(mode, &fa, &fb, &fc))
                    < 1e-10
            );
        }
        assert!(csf.slice_entries(4).len() == 1);
        assert!(CsfTensor::from_coo(CooTensor::new(2, 2, 2)).slice_entries(0).is_empty());
    }

    #[test]
    fn density_reports_fill() {
        let mut coo = CooTensor::new(2, 2, 2);
        coo.push(0, 0, 0, 1.0);
        coo.push(1, 1, 1, 1.0);
        let csf = CsfTensor::from_coo(coo);
        assert!((csf.density() - 0.25).abs() < 1e-12);
    }
}
