//! Compressed Sparse Fiber (CSF) tensor backend.
//!
//! The COO backend walks a flat entry list, which makes MTTKRP — the
//! dominant cost inside every sample ALS sweep — pay per *entry* for work
//! that is shared per *fiber* and per *root slice*: re-loading factor rows,
//! scattering into the output, and (in the parallel path) allocating,
//! zeroing and reducing full-size per-thread accumulators. CSF stores one
//! mode-rooted fiber tree per mode (built by sorting, then grown
//! incrementally on mode-3 append), so MTTKRP for mode `n` walks
//! orientation `n`:
//!
//! ```text
//! root r (output row)            — accumulated in registers, stored once
//! └── fiber (r, m)               — one mid-factor row load per fiber
//!     └── leaf entries (l, v)    — v · leaf_factor[l, :], contiguous
//! ```
//!
//! Parallelism: root ranges own disjoint output rows, so workers write
//! **disjoint spans of the caller-owned output buffer directly** — no
//! contention, no local accumulators, no reduction pass — unlike the COO
//! path, which must merge full `out_dim × R` partials. Ranges are balanced
//! by entry count (heavy-tailed real data concentrates nnz on few roots).
//!
//! Memory: each orientation owns its values in its own order (3× the COO
//! value payload). That trade is deliberate — the accumulated tensor is
//! read by `3 · iters · reps` MTTKRPs per ingest, while mode-3 growth only
//! pays a sort of the *batch* plus a linear splice (see
//! [`CsfTensor::append_mode3`]); the history is never re-sorted.

use super::sparse::{inverse_map, mode3_shift};
use super::{
    masked_normals_accumulate, masked_normals_prepare, mode_dim, CooTensor, DenseTensor, Tensor3,
};
use crate::linalg::Matrix;
use crate::util::par::workers_for;

/// One mode-rooted fiber tree. All pointer arrays are `u32` (nnz beyond 4B
/// entries is out of scope for this testbed, as in the COO backend).
#[derive(Clone, Default)]
struct Orientation {
    /// Distinct root indices, ascending.
    roots: Vec<u32>,
    /// Fibers of root `f` are `fiber_ptr[f]..fiber_ptr[f+1]` (into `mids`).
    fiber_ptr: Vec<u32>,
    /// Mid-level index per fiber.
    mids: Vec<u32>,
    /// Entries of fiber `g` are `entry_ptr[g]..entry_ptr[g+1]`.
    entry_ptr: Vec<u32>,
    /// Leaf-level index per entry, fiber-contiguous.
    leaves: Vec<u32>,
    /// Value per entry, in this orientation's order.
    vals: Vec<f64>,
}

impl Orientation {
    /// Entry range (into `leaves`/`vals`) owned by root `f` — contiguous
    /// because fibers and entries are laid out in root-major order.
    #[inline]
    fn root_entries(&self, f: usize) -> std::ops::Range<usize> {
        let e0 = self.entry_ptr[self.fiber_ptr[f] as usize] as usize;
        let e1 = self.entry_ptr[self.fiber_ptr[f + 1] as usize] as usize;
        e0..e1
    }

    /// Copy with every root index rebased by `shift` — the adopt-the-batch
    /// fallback of [`append_orientation_tail`] when the accumulator is
    /// empty (the non-empty path rebases during the extend instead).
    fn with_shifted_roots(&self, shift: u32) -> Orientation {
        let mut o = self.clone();
        for r in &mut o.roots {
            *r += shift;
        }
        o
    }
}

/// How many (root) and (root, mid) coordinates appear in *both* trees —
/// the tree levels a merge shares rather than adds (entries never merge:
/// a mode-3 append rebases every batch `k` past the existing extent, so
/// leaf coordinates are always disjoint). One gallop pass, `O(batch·log)`.
fn count_shared(old: &Orientation, new: &Orientation) -> (usize, usize) {
    let (mut a, mut b) = (0usize, 0usize);
    let (mut roots, mut fibers) = (0usize, 0usize);
    while a < old.roots.len() && b < new.roots.len() {
        match old.roots[a].cmp(&new.roots[b]) {
            std::cmp::Ordering::Less => {
                a += old.roots[a..].partition_point(|&r| r < new.roots[b]);
            }
            std::cmp::Ordering::Greater => {
                b += new.roots[b..].partition_point(|&r| r < old.roots[a]);
            }
            std::cmp::Ordering::Equal => {
                roots += 1;
                let (mut ga, a1) = (old.fiber_ptr[a] as usize, old.fiber_ptr[a + 1] as usize);
                let (mut gb, b1) = (new.fiber_ptr[b] as usize, new.fiber_ptr[b + 1] as usize);
                while ga < a1 && gb < b1 {
                    match old.mids[ga].cmp(&new.mids[gb]) {
                        std::cmp::Ordering::Less => {
                            ga += old.mids[ga..a1].partition_point(|&m| m < new.mids[gb]);
                        }
                        std::cmp::Ordering::Greater => {
                            gb += new.mids[gb..b1].partition_point(|&m| m < old.mids[ga]);
                        }
                        std::cmp::Ordering::Equal => {
                            fibers += 1;
                            ga += 1;
                            gb += 1;
                        }
                    }
                }
                a += 1;
                b += 1;
            }
        }
    }
    (roots, fibers)
}

/// Cursor state of one in-place splice: read frontiers over the old tree
/// (exclusive ends of the not-yet-placed prefix, per level — the suffix
/// past each frontier has already been moved to its final position) and
/// write frontiers over the output layout. The safety invariant is
/// `write frontier ≥ read frontier` at every level (the merged tree is
/// never smaller than the old one at any suffix), so back-to-front
/// placement always reads a slot before anything overwrites it.
struct Splice<'a> {
    new: &'a Orientation,
    leaf_shift: u32,
    /// Old-side read frontiers: fibers `0..ga_end` / entries `0..ea_end`
    /// are still unplaced (their pointer slots are still original).
    ga_end: usize,
    ea_end: usize,
    /// Output write frontiers (exclusive), per level.
    wa: usize,
    wg: usize,
    we: usize,
}

impl Splice<'_> {
    /// Move old fibers `g0..ga_end` (with their entries) to the write
    /// frontier: two overlapping `copy_within` moves plus descending
    /// pointer rebases (write slots are always ≥ read slots, so iterating
    /// high-to-low never clobbers an unread value).
    fn place_old_fibers(&mut self, old: &mut Orientation, g0: usize) {
        let e0 = old.entry_ptr[g0] as usize;
        let (ng, ne) = (self.ga_end - g0, self.ea_end - e0);
        old.leaves.copy_within(e0..self.ea_end, self.we - ne);
        old.vals.copy_within(e0..self.ea_end, self.we - ne);
        let de = (self.we - ne - e0) as u32;
        for t in (0..ng).rev() {
            old.entry_ptr[self.wg - ng + t] = old.entry_ptr[g0 + t] + de;
        }
        old.mids.copy_within(g0..self.ga_end, self.wg - ng);
        self.ga_end = g0;
        self.ea_end = e0;
        self.wg -= ng;
        self.we -= ne;
    }

    /// Copy batch fibers `g0..g1` (with their entries) to the write
    /// frontier, rebasing every leaf by `leaf_shift` as it lands.
    fn place_batch_fibers(&mut self, old: &mut Orientation, g0: usize, g1: usize) {
        let e0 = self.new.entry_ptr[g0] as usize;
        let e1 = self.new.entry_ptr[g1] as usize;
        let (ng, ne) = (g1 - g0, e1 - e0);
        for t in 0..ne {
            old.leaves[self.we - ne + t] = self.new.leaves[e0 + t] + self.leaf_shift;
        }
        old.vals[self.we - ne..self.we].copy_from_slice(&self.new.vals[e0..e1]);
        let base = (self.we - ne) as u32 - e0 as u32;
        for t in 0..ng {
            old.entry_ptr[self.wg - ng + t] = self.new.entry_ptr[g0 + t] + base;
        }
        old.mids[self.wg - ng..self.wg].copy_from_slice(&self.new.mids[g0..g1]);
        self.wg -= ng;
        self.we -= ne;
    }

    /// Move old roots `f0..f1` with their whole subtrees (`f1` must be the
    /// root read frontier).
    fn place_old_roots(&mut self, old: &mut Orientation, f0: usize, f1: usize) {
        let g0 = old.fiber_ptr[f0] as usize;
        let nr = f1 - f0;
        self.place_old_fibers(old, g0);
        let dg = (self.wg - g0) as u32;
        for t in (0..nr).rev() {
            old.fiber_ptr[self.wa - nr + t] = old.fiber_ptr[f0 + t] + dg;
        }
        old.roots.copy_within(f0..f1, self.wa - nr);
        self.wa -= nr;
    }

    /// Copy batch roots `b0..b1` with their whole subtrees.
    fn place_batch_roots(&mut self, old: &mut Orientation, b0: usize, b1: usize) {
        let g0 = self.new.fiber_ptr[b0] as usize;
        let g1 = self.new.fiber_ptr[b1] as usize;
        let nr = b1 - b0;
        self.place_batch_fibers(old, g0, g1);
        let base = self.wg as u32 - g0 as u32;
        for t in 0..nr {
            old.fiber_ptr[self.wa - nr + t] = self.new.fiber_ptr[b0 + t] + base;
        }
        old.roots[self.wa - nr..self.wa].copy_from_slice(&self.new.roots[b0..b1]);
        self.wa -= nr;
    }

    /// Merge one root present in both trees (old root `fa`, batch root
    /// `fb`): fibers interleave in descending mid order; a fiber present
    /// in both emits the batch entries *above* the old ones — the forward
    /// order "old entries then batch entries", placed back-to-front —
    /// which is exact because a mode-3 append rebases every batch leaf
    /// strictly past the old extent.
    fn merge_shared_root(&mut self, old: &mut Orientation, fa: usize, fb: usize) {
        let ga0 = old.fiber_ptr[fa] as usize;
        let gb0 = self.new.fiber_ptr[fb] as usize;
        let mut gb = self.new.fiber_ptr[fb + 1] as usize;
        while self.ga_end > ga0 && gb > gb0 {
            let (ma, mb) = (old.mids[self.ga_end - 1], self.new.mids[gb - 1]);
            match ma.cmp(&mb) {
                std::cmp::Ordering::Greater => {
                    let run = ga0 + old.mids[ga0..self.ga_end].partition_point(|&m| m <= mb);
                    self.place_old_fibers(old, run);
                }
                std::cmp::Ordering::Less => {
                    let run = gb0 + self.new.mids[gb0..gb].partition_point(|&m| m <= ma);
                    self.place_batch_fibers(old, run, gb);
                    gb = run;
                }
                std::cmp::Ordering::Equal => {
                    let eb0 = self.new.entry_ptr[gb - 1] as usize;
                    let eb1 = self.new.entry_ptr[gb] as usize;
                    let nb = eb1 - eb0;
                    for t in 0..nb {
                        old.leaves[self.we - nb + t] = self.new.leaves[eb0 + t] + self.leaf_shift;
                    }
                    old.vals[self.we - nb..self.we].copy_from_slice(&self.new.vals[eb0..eb1]);
                    self.we -= nb;
                    let ea0 = old.entry_ptr[self.ga_end - 1] as usize;
                    let na = self.ea_end - ea0;
                    old.leaves.copy_within(ea0..self.ea_end, self.we - na);
                    old.vals.copy_within(ea0..self.ea_end, self.we - na);
                    self.we -= na;
                    old.entry_ptr[self.wg - 1] = self.we as u32;
                    old.mids[self.wg - 1] = ma;
                    self.wg -= 1;
                    self.ga_end -= 1;
                    self.ea_end = ea0;
                    gb -= 1;
                }
            }
        }
        if self.ga_end > ga0 {
            self.place_old_fibers(old, ga0);
        }
        if gb > gb0 {
            self.place_batch_fibers(old, gb0, gb);
        }
        old.fiber_ptr[self.wa - 1] = self.wg as u32;
        old.roots[self.wa - 1] = self.new.roots[fb];
        self.wa -= 1;
    }
}

/// Merge a batch tree into `old` **in place** under the mode-3-append
/// precondition (shared fibers: batch leaves strictly after old leaves,
/// rebased by `new_leaf_shift` as they land — no shifted clone is built).
///
/// One counting gallop sizes the merged levels exactly (entries never
/// merge, so only root/fiber slots can be shared), the arrays grow to
/// final size with `Vec::resize`, and a tail-first back-to-front pass
/// splices the batch in: untouched old subtree spans move as bulk
/// `copy_within` memmoves, and the walk **stops at the smallest batch
/// root** — the old prefix below it is already in its final position and
/// is never touched. Cost is `O(rows ≥ min batch root)` memmove plus work
/// proportional to the batch, with no fresh allocation of the history
/// (capacity grows amortised like any `Vec`), versus the previous
/// rebuild-into-fresh-arrays merge that re-wrote all `O(nnz)` entries
/// every batch.
fn merge_orientation_in_place(old: &mut Orientation, new: &Orientation, new_leaf_shift: u32) {
    if new.roots.is_empty() {
        return;
    }
    let (shared_roots, shared_fibers) = count_shared(old, new);
    let (old_roots, old_fibers, old_entries) = (old.roots.len(), old.mids.len(), old.vals.len());
    let out_roots = old_roots + new.roots.len() - shared_roots;
    let out_fibers = old_fibers + new.mids.len() - shared_fibers;
    let out_entries = old_entries + new.vals.len();
    old.roots.resize(out_roots, 0);
    old.fiber_ptr.resize(out_roots + 1, 0);
    old.mids.resize(out_fibers, 0);
    old.entry_ptr.resize(out_fibers + 1, 0);
    old.leaves.resize(out_entries, 0);
    old.vals.resize(out_entries, 0.0);
    old.fiber_ptr[out_roots] = out_fibers as u32;
    old.entry_ptr[out_fibers] = out_entries as u32;
    let mut s = Splice {
        new,
        leaf_shift: new_leaf_shift,
        ga_end: old_fibers,
        ea_end: old_entries,
        wa: out_roots,
        wg: out_fibers,
        we: out_entries,
    };
    let mut ra = old_roots; // old roots 0..ra unplaced
    let mut rb = new.roots.len(); // batch roots 0..rb unplaced
    while rb > 0 {
        if ra > 0 && old.roots[ra - 1] > new.roots[rb - 1] {
            let run = old.roots[..ra].partition_point(|&r| r <= new.roots[rb - 1]);
            s.place_old_roots(old, run, ra);
            ra = run;
        } else if ra == 0 || new.roots[rb - 1] > old.roots[ra - 1] {
            let run = if ra == 0 {
                0
            } else {
                new.roots[..rb].partition_point(|&r| r <= old.roots[ra - 1])
            };
            s.place_batch_roots(old, run, rb);
            rb = run;
        } else {
            s.merge_shared_root(old, ra - 1, rb - 1);
            ra -= 1;
            rb -= 1;
        }
    }
    // Batch exhausted: the remaining old prefix is already in place (its
    // write frontier met its read frontier at every level).
    debug_assert_eq!((s.wa, s.wg, s.we), (ra, s.ga_end, s.ea_end));
}

/// Append a tree whose roots (after adding `root_shift`) all sort strictly
/// after `old`'s — the mode-3 tree under a mode-3 append. Pure
/// concatenation with pointer rebasing: `O(nnz_batch)`, the existing
/// arrays are extended in place and the batch payload is copied exactly
/// once (roots rebase during the extend — no shifted intermediate clone).
fn append_orientation_tail(old: &mut Orientation, new: &Orientation, root_shift: u32) {
    if new.roots.is_empty() {
        return;
    }
    if old.roots.is_empty() {
        *old = new.with_shifted_roots(root_shift);
        return;
    }
    debug_assert!(*old.roots.last().unwrap() < new.roots[0] + root_shift);
    old.fiber_ptr.pop();
    old.entry_ptr.pop();
    let fiber_base = old.mids.len() as u32;
    let leaf_base = old.leaves.len() as u32;
    old.roots.extend(new.roots.iter().map(|&r| r + root_shift));
    old.fiber_ptr.extend(new.fiber_ptr.iter().map(|&g| g + fiber_base));
    old.mids.extend_from_slice(&new.mids);
    old.entry_ptr.extend(new.entry_ptr.iter().map(|&e| e + leaf_base));
    old.leaves.extend_from_slice(&new.leaves);
    old.vals.extend_from_slice(&new.vals);
}

/// Build the orientation whose root level is `mode`. `(root, mid, leaf)`
/// per mode: 0 → (i, j, k), 1 → (j, i, k), 2 → (k, j, i) — the leaf/mid
/// assignment pairs each orientation with the factor matrices its MTTKRP
/// needs (`mode 0: Σ_j B[j] ∘ Σ_k v·C[k]`, etc.).
fn build_orientation(ii: &[u32], jj: &[u32], kk: &[u32], vv: &[f64], mode: usize) -> Orientation {
    let (rs, ms, ls): (&[u32], &[u32], &[u32]) = match mode {
        0 => (ii, jj, kk),
        1 => (jj, ii, kk),
        2 => (kk, jj, ii),
        _ => panic!("mode {mode} out of range for a 3-mode tensor"),
    };
    let n = vv.len();
    let mut order: Vec<u32> = (0..n as u32).collect();
    order.sort_unstable_by_key(|&e| {
        let e = e as usize;
        (rs[e], ms[e], ls[e])
    });
    let mut o = Orientation {
        leaves: Vec::with_capacity(n),
        vals: Vec::with_capacity(n),
        ..Orientation::default()
    };
    for &e in &order {
        let e = e as usize;
        let (root, mid, leaf, v) = (rs[e], ms[e], ls[e], vv[e]);
        let new_root = o.roots.last() != Some(&root);
        if new_root {
            o.roots.push(root);
            o.fiber_ptr.push(o.mids.len() as u32);
        }
        if new_root || o.mids.last() != Some(&mid) {
            o.mids.push(mid);
            o.entry_ptr.push(o.leaves.len() as u32);
        }
        o.leaves.push(leaf);
        o.vals.push(v);
    }
    o.fiber_ptr.push(o.mids.len() as u32);
    o.entry_ptr.push(o.leaves.len() as u32);
    o
}

/// Filter one orientation through per-level inverse maps (old index →
/// sampled position, `None` = unsampled), producing the extracted
/// orientation directly. A root absent from the sample skips its whole
/// subtree, an absent fiber skips its leaves; roots/fibers are emitted only
/// when at least one leaf survives (the same only-non-empty invariant
/// [`build_orientation`] maintains). Requires monotone maps — i.e. sorted
/// index sets — so the surviving runs stay in sorted order.
fn extract_orientation(
    src: &Orientation,
    inv_root: &[Option<u32>],
    inv_mid: &[Option<u32>],
    inv_leaf: &[Option<u32>],
) -> Orientation {
    let mut o = Orientation::default();
    for f in 0..src.roots.len() {
        let Some(nr) = inv_root[src.roots[f] as usize] else {
            continue;
        };
        let mut root_open = false;
        for g in src.fiber_ptr[f] as usize..src.fiber_ptr[f + 1] as usize {
            let Some(nm) = inv_mid[src.mids[g] as usize] else {
                continue;
            };
            let mut fiber_open = false;
            for e in src.entry_ptr[g] as usize..src.entry_ptr[g + 1] as usize {
                let Some(nl) = inv_leaf[src.leaves[e] as usize] else {
                    continue;
                };
                if !root_open {
                    o.roots.push(nr);
                    o.fiber_ptr.push(o.mids.len() as u32);
                    root_open = true;
                }
                if !fiber_open {
                    o.mids.push(nm);
                    o.entry_ptr.push(o.leaves.len() as u32);
                    fiber_open = true;
                }
                o.leaves.push(nl);
                o.vals.push(src.vals[e]);
            }
        }
    }
    o.fiber_ptr.push(o.mids.len() as u32);
    o.entry_ptr.push(o.leaves.len() as u32);
    o
}

/// CSF sparse tensor: three mode-rooted fiber trees over one coalesced
/// entry set. Mode-3 growth is incremental — new slices concatenate onto
/// the mode-3 tree and merge into the other two without re-sorting the
/// accumulated entries (see [`CsfTensor::append_mode3`]).
#[derive(Clone)]
pub struct CsfTensor {
    dims: (usize, usize, usize),
    nnz: usize,
    orient: [Orientation; 3],
}

impl std::fmt::Debug for CsfTensor {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "CsfTensor({}x{}x{}, nnz={}, roots={}/{}/{})",
            self.dims.0,
            self.dims.1,
            self.dims.2,
            self.nnz,
            self.orient[0].roots.len(),
            self.orient[1].roots.len(),
            self.orient[2].roots.len()
        )
    }
}

impl CsfTensor {
    /// Build from COO. Coalesces first (CSF requires unique coordinates;
    /// duplicates sum, exact zeros drop — standard COO semantics).
    pub fn from_coo(mut coo: CooTensor) -> Self {
        coo.coalesce();
        let dims = coo.dims();
        let n = coo.nnz();
        let mut ii = Vec::with_capacity(n);
        let mut jj = Vec::with_capacity(n);
        let mut kk = Vec::with_capacity(n);
        let mut vv = Vec::with_capacity(n);
        for (i, j, k, v) in coo.iter() {
            ii.push(i as u32);
            jj.push(j as u32);
            kk.push(k as u32);
            vv.push(v);
        }
        CsfTensor {
            dims,
            nnz: n,
            orient: [
                build_orientation(&ii, &jj, &kk, &vv, 0),
                build_orientation(&ii, &jj, &kk, &vv, 1),
                build_orientation(&ii, &jj, &kk, &vv, 2),
            ],
        }
    }

    pub fn from_dense(d: &DenseTensor, threshold: f64) -> Self {
        Self::from_coo(CooTensor::from_dense(d, threshold))
    }

    /// Entry iterator `(i, j, k, v)` in `(i, j, k)`-sorted order.
    pub fn iter(&self) -> impl Iterator<Item = (usize, usize, usize, f64)> + '_ {
        let o = &self.orient[0];
        (0..o.roots.len()).flat_map(move |f| {
            let i = o.roots[f] as usize;
            (o.fiber_ptr[f] as usize..o.fiber_ptr[f + 1] as usize).flat_map(move |g| {
                let j = o.mids[g] as usize;
                (o.entry_ptr[g] as usize..o.entry_ptr[g + 1] as usize)
                    .map(move |e| (i, j, o.leaves[e] as usize, o.vals[e]))
            })
        })
    }

    pub fn to_coo(&self) -> CooTensor {
        let mut out =
            CooTensor::with_capacity(self.dims.0, self.dims.1, self.dims.2, self.nnz);
        for (i, j, k, v) in self.iter() {
            out.push(i, j, k, v);
        }
        out
    }

    pub fn to_dense(&self) -> DenseTensor {
        let (ni, nj, nk) = self.dims;
        let mut d = DenseTensor::zeros(ni, nj, nk);
        for (i, j, k, v) in self.iter() {
            d.add_at(i, j, k, v);
        }
        d
    }

    /// Extract the sub-tensor at the given index lists by walking the
    /// mode-1 fiber tree: a root absent from `is` skips its whole subtree
    /// and a fiber absent from `js` skips all its leaves — the win over the
    /// COO scan, which tests every nonzero against all three maps. This
    /// runs `r` times per ingest (once per sampling repetition).
    pub fn extract(&self, is: &[usize], js: &[usize], ks: &[usize]) -> CooTensor {
        let inv_i = inverse_map(self.dims.0, is);
        let inv_j = inverse_map(self.dims.1, js);
        let inv_k = inverse_map(self.dims.2, ks);
        let o = &self.orient[0];
        let mut out = CooTensor::new(is.len(), js.len(), ks.len());
        for f in 0..o.roots.len() {
            let Some(ni) = inv_i[o.roots[f] as usize] else {
                continue;
            };
            for g in o.fiber_ptr[f] as usize..o.fiber_ptr[f + 1] as usize {
                let Some(nj) = inv_j[o.mids[g] as usize] else {
                    continue;
                };
                for e in o.entry_ptr[g] as usize..o.entry_ptr[g + 1] as usize {
                    let Some(nk) = inv_k[o.leaves[e] as usize] else {
                        continue;
                    };
                    out.push(ni as usize, nj as usize, nk as usize, o.vals[e]);
                }
            }
        }
        out
    }

    /// [`CsfTensor::extract`] emitting CSF directly — the large-sample path
    /// (small `s`) of [`super::TensorData::extract`], where the extracted
    /// tensor is big enough that its own sample-ALS sweeps should run on
    /// the fiber-tree kernels.
    ///
    /// With **sorted-ascending** index sets (the sampler's documented
    /// contract) the inverse maps are monotone, so walking each source
    /// orientation yields the output's entries already in that
    /// orientation's sort order: all three output trees build in one
    /// filtered pass each, with **no sort and no COO round trip** —
    /// `O(nnz_source)` total instead of the `O(nnz_out log nnz_out)` per
    /// orientation a `from_coo` rebuild would pay. Unsorted index sets
    /// (never produced by the sampler) fall back to extract-then-rebuild.
    pub fn extract_csf(&self, is: &[usize], js: &[usize], ks: &[usize]) -> CsfTensor {
        let ascending = |idx: &[usize]| idx.windows(2).all(|w| w[0] < w[1]);
        if !(ascending(is) && ascending(js) && ascending(ks)) {
            return CsfTensor::from_coo(self.extract(is, js, ks));
        }
        let inv_i = inverse_map(self.dims.0, is);
        let inv_j = inverse_map(self.dims.1, js);
        let inv_k = inverse_map(self.dims.2, ks);
        // Per-orientation (root, mid, leaf) index levels mirror
        // `build_orientation`: 0 → (i, j, k), 1 → (j, i, k), 2 → (k, j, i).
        let orient = [
            extract_orientation(&self.orient[0], &inv_i, &inv_j, &inv_k),
            extract_orientation(&self.orient[1], &inv_j, &inv_i, &inv_k),
            extract_orientation(&self.orient[2], &inv_k, &inv_j, &inv_i),
        ];
        let nnz = orient[0].vals.len();
        debug_assert_eq!(nnz, orient[1].vals.len());
        debug_assert_eq!(nnz, orient[2].vals.len());
        CsfTensor { dims: (is.len(), js.len(), ks.len()), nnz, orient }
    }

    /// Entries of frontal slice `k` as `(i, j, v)` triples, straight off
    /// the mode-3 tree (root = k) — the streaming replay primitive.
    pub fn slice_entries(&self, k: usize) -> Vec<(u32, u32, f64)> {
        let o = &self.orient[2];
        let Ok(f) = o.roots.binary_search(&(k as u32)) else {
            return Vec::new();
        };
        let mut out = Vec::new();
        for g in o.fiber_ptr[f] as usize..o.fiber_ptr[f + 1] as usize {
            let j = o.mids[g];
            for e in o.entry_ptr[g] as usize..o.entry_ptr[g + 1] as usize {
                // Orientation 2 is (root k, mid j, leaf i).
                out.push((o.leaves[e], j, o.vals[e]));
            }
        }
        out
    }

    /// Append `other` along mode 3 **incrementally**. Every batch `k`
    /// index is rebased past the existing mode-3 extent, so:
    ///
    /// * the mode-3-rooted tree gains its new roots by concatenation
    ///   (`O(nnz_batch)`, in place);
    /// * the mode-1/mode-2 trees merge the batch's sorted runs into the
    ///   existing fiber runs **in place**, back-to-front — new fibers
    ///   splice in, shared fibers extend at their tail, untouched subtree
    ///   spans move as bulk `copy_within` memmoves, and the splice stops
    ///   at the smallest batch root (the prefix below it never moves).
    ///
    /// Only the batch is ever *sorted* (`O(nnz_batch log nnz_batch)`);
    /// trees 0/1 pay at most a linear memmove of the entries above the
    /// batch's smallest root — no fresh arrays, no re-sort of the history
    /// (the old rebuild re-sorted all `O(nnz log nnz)` through COO; see
    /// [`merge_orientation_in_place`]).
    pub fn append_mode3(&mut self, other: &CooTensor) {
        let (oi, oj, k_new) = other.dims();
        assert_eq!(
            (self.dims.0, self.dims.1),
            (oi, oj),
            "mode-3 append requires matching modes 1-2"
        );
        let shift = mode3_shift(self.dims.2, k_new);
        // Batch-local coalesce matches the old global rebuild exactly: the
        // rebased `k` indices are disjoint from every existing entry, so
        // duplicates can only occur within the batch.
        let mut batch = other.clone();
        batch.coalesce();
        if batch.nnz() == 0 {
            self.dims.2 += k_new;
            return;
        }
        let (ii, jj, kk, vv) = batch.raw_parts();
        // The batch's `k` level is NOT pre-shifted: the rebase is monotone
        // (sort order unchanged), so the merge applies it during its copies
        // instead — one pass over the batch payload, no shifted clone.
        let b0 = build_orientation(ii, jj, kk, vv, 0);
        let b1 = build_orientation(ii, jj, kk, vv, 1);
        let b2 = build_orientation(ii, jj, kk, vv, 2);
        let nnz = vv.len();
        self.merge_batch(&b0, &b1, &b2, shift, nnz, k_new);
    }

    /// [`CsfTensor::append_mode3`] for a CSF batch, without materializing
    /// it as COO: each batch orientation is already the sorted run the
    /// merge needs — its `k` level (leaves of trees 0–1, roots of tree 2)
    /// is rebased during the merge copies themselves, so the batch trees
    /// are read in place and never cloned.
    pub fn append_mode3_csf(&mut self, other: &CsfTensor) {
        assert_eq!(
            (self.dims.0, self.dims.1),
            (other.dims.0, other.dims.1),
            "mode-3 append requires matching modes 1-2"
        );
        let shift = mode3_shift(self.dims.2, other.dims.2);
        if other.nnz == 0 {
            self.dims.2 += other.dims.2;
            return;
        }
        self.merge_batch(
            &other.orient[0],
            &other.orient[1],
            &other.orient[2],
            shift,
            other.nnz,
            other.dims.2,
        );
    }

    /// Shared tail of the two append paths: merge per-orientation batch
    /// runs, rebasing the batch's `k` level by `k_shift` as it is copied
    /// (leaves of `b0`/`b1` during the gallop/merge, roots of `b2` during
    /// the tail concat), then grow the bookkeeping.
    fn merge_batch(
        &mut self,
        b0: &Orientation,
        b1: &Orientation,
        b2: &Orientation,
        k_shift: u32,
        nnz: usize,
        k_new: usize,
    ) {
        // The fiber/entry pointer arrays are u32 (like the COO indices);
        // `mode3_shift` bounds the slice count, this bounds the entry
        // count — without it the `as u32` pointer rebases would wrap
        // silently in release builds once nnz crosses 4B.
        let total = self.nnz as u64 + nnz as u64;
        assert!(
            total <= u32::MAX as u64,
            "mode-3 append would grow nnz to {total}, past the u32 pointer \
             space of the CSF fiber trees"
        );
        merge_orientation_in_place(&mut self.orient[0], b0, k_shift);
        merge_orientation_in_place(&mut self.orient[1], b1, k_shift);
        append_orientation_tail(&mut self.orient[2], b2, k_shift);
        self.nnz += nnz;
        self.dims.2 += k_new;
    }

    /// Split along mode 3 at `at` (COO out: splits are transient stream
    /// plumbing, promotion re-applies where it pays).
    pub fn split_mode3(&self, at: usize) -> (CooTensor, CooTensor) {
        self.to_coo().split_mode3(at)
    }

    pub fn norm_sq(&self) -> f64 {
        self.orient[0].vals.iter().map(|v| v * v).sum()
    }

    /// Density in `[0, 1]`.
    pub fn density(&self) -> f64 {
        let total = self.dims.0 * self.dims.1 * self.dims.2;
        if total == 0 {
            0.0
        } else {
            self.nnz as f64 / total as f64
        }
    }
}

/// Contiguous root ranges with near-equal *entry* counts (roots are a poor
/// balance unit on heavy-tailed data where a few slices hold most nonzeros).
fn balanced_root_ranges(o: &Orientation, parts: usize) -> Vec<std::ops::Range<usize>> {
    let nroots = o.roots.len();
    if parts <= 1 || nroots <= 1 {
        return vec![0..nroots];
    }
    let per = o.vals.len().div_ceil(parts).max(1);
    let mut out = Vec::with_capacity(parts);
    let mut start = 0;
    let mut acc = 0;
    for f in 0..nroots {
        acc += o.root_entries(f).len();
        if acc >= per && f + 1 < nroots {
            out.push(start..f + 1);
            start = f + 1;
            acc = 0;
        }
    }
    out.push(start..nroots);
    out
}

/// Fiber-tree MTTKRP over a root range, compile-time rank, writing each
/// root's row into the **caller-owned** span `out_rows` (row-major, stride
/// `R`, covering output rows `row_base..`): the output row accumulates in
/// registers and stores once per root; each fiber loads its mid-factor row
/// once; leaf entries stream contiguously. Rows without a root in `range`
/// are never touched (the caller zeroes the buffer).
fn mttkrp_roots_const<const R: usize>(
    o: &Orientation,
    midf: &Matrix,
    leaff: &Matrix,
    range: std::ops::Range<usize>,
    row_base: usize,
    out_rows: &mut [f64],
) {
    for f in range {
        let mut acc = [0.0f64; R];
        for g in o.fiber_ptr[f] as usize..o.fiber_ptr[f + 1] as usize {
            let mut fib = [0.0f64; R];
            let es = o.entry_ptr[g] as usize..o.entry_ptr[g + 1] as usize;
            for (leaf, v) in o.leaves[es.clone()].iter().zip(&o.vals[es]) {
                let lrow = leaff.row(*leaf as usize);
                for t in 0..R {
                    fib[t] += v * lrow[t];
                }
            }
            let mrow = midf.row(o.mids[g] as usize);
            for t in 0..R {
                acc[t] += fib[t] * mrow[t];
            }
        }
        let row = o.roots[f] as usize - row_base;
        out_rows[row * R..row * R + R].copy_from_slice(&acc);
    }
}

/// Runtime-rank fallback of [`mttkrp_roots_const`]. The `fib` scratch is
/// the only allocation on the runtime-rank path (one `Vec<f64>` of length
/// `r` per worker per call).
fn mttkrp_roots_generic(
    o: &Orientation,
    midf: &Matrix,
    leaff: &Matrix,
    range: std::ops::Range<usize>,
    row_base: usize,
    out_rows: &mut [f64],
) {
    let r = midf.cols();
    let mut fib = vec![0.0f64; r];
    for f in range {
        let row = o.roots[f] as usize - row_base;
        let out = &mut out_rows[row * r..row * r + r];
        for g in o.fiber_ptr[f] as usize..o.fiber_ptr[f + 1] as usize {
            fib.iter_mut().for_each(|x| *x = 0.0);
            let es = o.entry_ptr[g] as usize..o.entry_ptr[g + 1] as usize;
            for (leaf, v) in o.leaves[es.clone()].iter().zip(&o.vals[es]) {
                let lrow = leaff.row(*leaf as usize);
                for t in 0..r {
                    fib[t] += v * lrow[t];
                }
            }
            let mrow = midf.row(o.mids[g] as usize);
            for t in 0..r {
                out[t] += fib[t] * mrow[t];
            }
        }
    }
}

/// Rank dispatch shared by the serial and parallel paths of
/// [`CsfTensor::mttkrp_into`].
fn mttkrp_roots_dispatch(
    o: &Orientation,
    midf: &Matrix,
    leaff: &Matrix,
    r: usize,
    range: std::ops::Range<usize>,
    row_base: usize,
    out_rows: &mut [f64],
) {
    match r {
        1 => mttkrp_roots_const::<1>(o, midf, leaff, range, row_base, out_rows),
        2 => mttkrp_roots_const::<2>(o, midf, leaff, range, row_base, out_rows),
        3 => mttkrp_roots_const::<3>(o, midf, leaff, range, row_base, out_rows),
        4 => mttkrp_roots_const::<4>(o, midf, leaff, range, row_base, out_rows),
        5 => mttkrp_roots_const::<5>(o, midf, leaff, range, row_base, out_rows),
        6 => mttkrp_roots_const::<6>(o, midf, leaff, range, row_base, out_rows),
        8 => mttkrp_roots_const::<8>(o, midf, leaff, range, row_base, out_rows),
        10 => mttkrp_roots_const::<10>(o, midf, leaff, range, row_base, out_rows),
        16 => mttkrp_roots_const::<16>(o, midf, leaff, range, row_base, out_rows),
        _ => mttkrp_roots_generic(o, midf, leaff, range, row_base, out_rows),
    }
}

impl Tensor3 for CsfTensor {
    fn dims(&self) -> (usize, usize, usize) {
        self.dims
    }

    fn norm(&self) -> f64 {
        self.norm_sq().sqrt()
    }

    fn nnz(&self) -> usize {
        self.nnz
    }

    fn mttkrp_into(&self, mode: usize, a: &Matrix, b: &Matrix, c: &Matrix, out: &mut Matrix) {
        let r = a.cols();
        debug_assert_eq!(b.cols(), r);
        debug_assert_eq!(c.cols(), r);
        // Mid/leaf factors per orientation — see `build_orientation`.
        let (midf, leaff) = match mode {
            0 => (b, c),
            1 => (a, c),
            2 => (b, a),
            _ => panic!("mode {mode} out of range"),
        };
        let o = &self.orient[mode];
        let nrows = mode_dim(self.dims, mode);
        assert_eq!(
            (out.rows(), out.cols()),
            (nrows, r),
            "mttkrp_into out-buffer shape mismatch"
        );
        out.fill(0.0);
        if o.roots.is_empty() {
            return;
        }
        let nw = workers_for(self.nnz / 4096 + 1).min(o.roots.len());
        let ranges = balanced_root_ranges(o, nw);
        if ranges.len() == 1 {
            mttkrp_roots_dispatch(o, midf, leaff, r, 0..o.roots.len(), 0, out.data_mut());
            return;
        }
        // Root ranges partition the ascending root list, so the workers own
        // disjoint, ascending *output-row* intervals: split the caller's
        // buffer at each range's first root row and hand every worker its
        // own span. No local accumulators, no reduction, no scatter pass —
        // the caller-owned buffer is the only output memory touched.
        let nranges = ranges.len();
        let mut tasks = Vec::with_capacity(nranges);
        let mut rest: &mut [f64] = out.data_mut();
        let mut consumed = 0usize; // output rows already split off
        for (w, range) in ranges.iter().enumerate() {
            let base = o.roots[range.start] as usize;
            let end = if w + 1 < nranges {
                o.roots[ranges[w + 1].start] as usize
            } else {
                nrows
            };
            let tail = std::mem::take(&mut rest);
            // Rows `consumed..base` hold no root of any range in this
            // split; they stay zero and belong to no worker.
            let (_gap, tail) = tail.split_at_mut((base - consumed) * r);
            let (span, tail) = tail.split_at_mut((end - base) * r);
            rest = tail;
            consumed = end;
            tasks.push((range.clone(), base, span));
        }
        std::thread::scope(|s| {
            for (range, base, span) in tasks {
                s.spawn(move || mttkrp_roots_dispatch(o, midf, leaff, r, range, base, span));
            }
        });
    }

    fn mode_sum_squares(&self, mode: usize) -> Vec<f64> {
        let o = &self.orient[mode];
        let mut out = vec![0.0; mode_dim(self.dims, mode)];
        for f in 0..o.roots.len() {
            out[o.roots[f] as usize] =
                o.vals[o.root_entries(f)].iter().map(|v| v * v).sum();
        }
        out
    }

    fn inner_with_kruskal(&self, lambda: &[f64], a: &Matrix, b: &Matrix, c: &Matrix) -> f64 {
        let r = lambda.len();
        let o = &self.orient[0];
        let mut acc = 0.0;
        let mut rootacc = vec![0.0f64; r];
        let mut fib = vec![0.0f64; r];
        for f in 0..o.roots.len() {
            rootacc.iter_mut().for_each(|x| *x = 0.0);
            for g in o.fiber_ptr[f] as usize..o.fiber_ptr[f + 1] as usize {
                fib.iter_mut().for_each(|x| *x = 0.0);
                let es = o.entry_ptr[g] as usize..o.entry_ptr[g + 1] as usize;
                for (leaf, v) in o.leaves[es.clone()].iter().zip(&o.vals[es]) {
                    let crow = c.row(*leaf as usize);
                    for t in 0..r {
                        fib[t] += v * crow[t];
                    }
                }
                let brow = b.row(o.mids[g] as usize);
                for t in 0..r {
                    rootacc[t] += fib[t] * brow[t];
                }
            }
            let arow = a.row(o.roots[f] as usize);
            for t in 0..r {
                acc += lambda[t] * arow[t] * rootacc[t];
            }
        }
        acc
    }

    fn masked_normals_into(
        &self,
        mode: usize,
        a: &Matrix,
        b: &Matrix,
        c: &Matrix,
        rhs: &mut Matrix,
        grams: &mut Matrix,
    ) {
        let r = a.cols();
        masked_normals_prepare(self.dims, mode, r, rhs, grams);
        // Walk orientation `mode` like its MTTKRP (root = output row, one
        // mid-factor row load per fiber), but the Khatri-Rao row `w` is
        // per *entry* — the gram accumulation cannot hoist past the leaf
        // loop the way the MTTKRP's register accumulator can.
        let (midf, leaff) = match mode {
            0 => (b, c),
            1 => (a, c),
            2 => (b, a),
            _ => panic!("mode {mode} out of range"),
        };
        let o = &self.orient[mode];
        let mut w = vec![0.0f64; r];
        for f in 0..o.roots.len() {
            let dst = o.roots[f] as usize;
            for g in o.fiber_ptr[f] as usize..o.fiber_ptr[f + 1] as usize {
                let mrow = midf.row(o.mids[g] as usize);
                let es = o.entry_ptr[g] as usize..o.entry_ptr[g + 1] as usize;
                for (leaf, v) in o.leaves[es.clone()].iter().zip(&o.vals[es]) {
                    let lrow = leaff.row(*leaf as usize);
                    for t in 0..r {
                        w[t] = mrow[t] * lrow[t];
                    }
                    masked_normals_accumulate(rhs, grams, dst, *v, &w);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn roundtrips_coo_exactly() {
        let mut rng = Rng::new(1);
        let coo = CooTensor::rand(7, 6, 5, 0.3, &mut rng);
        let csf = CsfTensor::from_coo(coo.clone());
        assert_eq!(csf.nnz(), coo.nnz());
        assert!((csf.norm() - coo.norm()).abs() < 1e-12);
        let d1 = csf.to_coo().to_dense();
        let d2 = coo.to_dense();
        assert_eq!(d1.data(), d2.data());
        assert_eq!(csf.to_dense().data(), d2.data());
    }

    #[test]
    fn from_coo_coalesces_duplicates() {
        let mut coo = CooTensor::new(3, 3, 3);
        coo.push(1, 1, 1, 2.0);
        coo.push(1, 1, 1, 3.0);
        coo.push(0, 2, 2, 1.0);
        coo.push(0, 2, 2, -1.0); // cancels
        let csf = CsfTensor::from_coo(coo);
        assert_eq!(csf.nnz(), 1);
        assert_eq!(csf.iter().next().unwrap(), (1, 1, 1, 5.0));
    }

    #[test]
    fn mttkrp_matches_dense_all_modes() {
        let mut rng = Rng::new(2);
        for r in [1usize, 2, 3, 4, 7, 8, 16] {
            let coo = CooTensor::rand(9, 8, 7, 0.3, &mut rng);
            let dense = coo.to_dense();
            let csf = CsfTensor::from_coo(coo);
            let a = Matrix::rand_gaussian(9, r, &mut rng);
            let b = Matrix::rand_gaussian(8, r, &mut rng);
            let c = Matrix::rand_gaussian(7, r, &mut rng);
            for mode in 0..3 {
                let mc = csf.mttkrp(mode, &a, &b, &c);
                let md = dense.mttkrp(mode, &a, &b, &c);
                assert!(mc.max_abs_diff(&md) < 1e-10, "rank {r} mode {mode}");
            }
        }
    }

    #[test]
    fn mttkrp_parallel_ranges_cover_all_roots() {
        // Large enough nnz to force multiple worker ranges.
        let mut rng = Rng::new(3);
        let coo = CooTensor::rand(50, 40, 30, 0.4, &mut rng);
        assert!(coo.nnz() > 8192);
        let dense = coo.to_dense();
        let csf = CsfTensor::from_coo(coo);
        let a = Matrix::rand_gaussian(50, 4, &mut rng);
        let b = Matrix::rand_gaussian(40, 4, &mut rng);
        let c = Matrix::rand_gaussian(30, 4, &mut rng);
        for mode in 0..3 {
            let mc = csf.mttkrp(mode, &a, &b, &c);
            let md = dense.mttkrp(mode, &a, &b, &c);
            assert!(mc.max_abs_diff(&md) < 1e-9, "mode {mode}");
        }
    }

    #[test]
    fn mode_sum_squares_and_inner_match_dense() {
        let mut rng = Rng::new(4);
        let coo = CooTensor::rand(8, 7, 6, 0.4, &mut rng);
        let dense = coo.to_dense();
        let csf = CsfTensor::from_coo(coo);
        for mode in 0..3 {
            let sc = csf.mode_sum_squares(mode);
            let sd = dense.mode_sum_squares(mode);
            for (x, y) in sc.iter().zip(&sd) {
                assert!((x - y).abs() < 1e-12);
            }
        }
        let a = Matrix::rand_gaussian(8, 3, &mut rng);
        let b = Matrix::rand_gaussian(7, 3, &mut rng);
        let c = Matrix::rand_gaussian(6, 3, &mut rng);
        let lam = vec![1.2, 0.5, 2.0];
        let ic = csf.inner_with_kruskal(&lam, &a, &b, &c);
        let id = dense.inner_with_kruskal(&lam, &a, &b, &c);
        assert!((ic - id).abs() < 1e-9);
    }

    #[test]
    fn extract_matches_coo_extract() {
        let mut rng = Rng::new(5);
        let coo = CooTensor::rand(10, 9, 8, 0.35, &mut rng);
        let csf = CsfTensor::from_coo(coo.clone());
        let is = vec![0, 3, 7, 9];
        let js = vec![8, 1, 4];
        let ks = vec![2, 5];
        let dc = csf.extract(&is, &js, &ks).to_dense();
        let dd = coo.extract(&is, &js, &ks).to_dense();
        assert_eq!(dc.data(), dd.data());
    }

    /// `extract_csf` must be *tree-identical* to rebuilding from the COO
    /// extraction — the shared checker probes dims, nnz, entry order and
    /// MTTKRP on all three orientations.
    #[test]
    fn extract_csf_matches_coo_extract_rebuild() {
        let mut rng = Rng::new(15);
        let coo = CooTensor::rand(12, 11, 10, 0.4, &mut rng);
        let csf = CsfTensor::from_coo(coo.clone());
        // Sorted sets (the sampler contract) — native tree-walk path.
        let is = vec![0, 2, 5, 9, 11];
        let js = vec![1, 4, 8];
        let ks = vec![0, 3, 7, 9];
        let got = csf.extract_csf(&is, &js, &ks);
        let want = coo.extract(&is, &js, &ks);
        crate::testing::assert_csf_matches_rebuild(&got, &want, 3, 0xE57, "sorted sets");
        // Degenerate sets: empty mode-3 sample, single index per mode.
        let got = csf.extract_csf(&[3], &[4], &[]);
        assert_eq!(got.dims(), (1, 1, 0));
        assert_eq!(got.nnz(), 0);
        let got = csf.extract_csf(&[3], &[4], &[5]);
        let want = coo.extract(&[3], &[4], &[5]);
        crate::testing::assert_csf_matches_rebuild(&got, &want, 1, 0xE58, "single indices");
    }

    /// Unsorted index sets (never produced by the sampler) take the
    /// rebuild fallback and must still be exactly right.
    #[test]
    fn extract_csf_unsorted_sets_fall_back_correctly() {
        let mut rng = Rng::new(16);
        let coo = CooTensor::rand(9, 8, 7, 0.4, &mut rng);
        let csf = CsfTensor::from_coo(coo.clone());
        let is = vec![7, 0, 3];
        let js = vec![2, 6];
        let ks = vec![5, 1, 4];
        let got = csf.extract_csf(&is, &js, &ks);
        let want = coo.extract(&is, &js, &ks);
        assert_eq!(got.nnz(), want.nnz());
        assert_eq!(got.to_dense().data(), want.to_dense().data());
    }

    /// A full-index extraction is the identity: the rebuilt trees must
    /// match the source exactly.
    #[test]
    fn extract_csf_full_sets_is_identity() {
        let mut rng = Rng::new(17);
        let coo = CooTensor::rand(6, 5, 4, 0.5, &mut rng);
        let csf = CsfTensor::from_coo(coo.clone());
        let is: Vec<usize> = (0..6).collect();
        let js: Vec<usize> = (0..5).collect();
        let ks: Vec<usize> = (0..4).collect();
        let got = csf.extract_csf(&is, &js, &ks);
        crate::testing::assert_csf_matches_rebuild(&got, &coo, 2, 0xE59, "identity");
    }

    /// `mttkrp_into` into a dirty reused buffer must be bit-identical to
    /// the allocating `mttkrp`, on both the serial and the parallel
    /// (multi-range, caller-owned-span) paths.
    #[test]
    fn mttkrp_into_dirty_buffer_matches_serial_and_parallel() {
        let mut rng = Rng::new(18);
        // Small (serial) and large (parallel root ranges) tensors.
        for (dim, density) in [(8usize, 0.4f64), (40, 0.5)] {
            let coo = CooTensor::rand(dim, dim, dim, density, &mut rng);
            let csf = CsfTensor::from_coo(coo);
            for r in [4usize, 7] {
                let a = Matrix::rand_gaussian(dim, r, &mut rng);
                let b = Matrix::rand_gaussian(dim, r, &mut rng);
                let c = Matrix::rand_gaussian(dim, r, &mut rng);
                for mode in 0..3 {
                    let want = csf.mttkrp(mode, &a, &b, &c);
                    let mut out = Matrix::from_fn(dim, r, |_, _| 1e30);
                    csf.mttkrp_into(mode, &a, &b, &c, &mut out);
                    assert_eq!(
                        out.max_abs_diff(&want),
                        0.0,
                        "dim {dim} rank {r} mode {mode}"
                    );
                }
            }
        }
    }

    #[test]
    fn slice_entries_match_iter_filter() {
        let mut rng = Rng::new(6);
        let coo = CooTensor::rand(6, 6, 6, 0.4, &mut rng);
        let csf = CsfTensor::from_coo(coo.clone());
        for k in 0..6 {
            let mut got: Vec<(usize, usize, f64)> = csf
                .slice_entries(k)
                .into_iter()
                .map(|(i, j, v)| (i as usize, j as usize, v))
                .collect();
            got.sort_by(|a, b| (a.0, a.1).cmp(&(b.0, b.1)));
            let mut want: Vec<(usize, usize, f64)> = csf
                .iter()
                .filter(|&(_, _, kk, _)| kk == k)
                .map(|(i, j, _, v)| (i, j, v))
                .collect();
            want.sort_by(|a, b| (a.0, a.1).cmp(&(b.0, b.1)));
            assert_eq!(got, want, "slice {k}");
        }
    }

    #[test]
    fn append_and_split_roundtrip() {
        let mut rng = Rng::new(7);
        let coo = CooTensor::rand(5, 5, 8, 0.4, &mut rng);
        let batch = CooTensor::rand(5, 5, 3, 0.4, &mut rng);
        let mut csf = CsfTensor::from_coo(coo.clone());
        csf.append_mode3(&batch);
        assert_eq!(csf.dims(), (5, 5, 11));
        let mut want = coo.clone();
        want.append_mode3(&batch);
        want.coalesce();
        assert_eq!(csf.to_dense().data(), want.to_dense().data());
        let (head, tail) = csf.split_mode3(8);
        let mut coalesced = coo;
        coalesced.coalesce();
        let want_head = coalesced.to_dense();
        assert_eq!(head.to_dense().data(), want_head.data());
        assert_eq!(tail.dims().2, 3);
    }

    /// Incremental append must be bit-identical to a rebuild from COO —
    /// the shared checker probes entry order plus MTTKRP on all three
    /// orientations.
    fn assert_matches_rebuild(incremental: &CsfTensor, reference: &CooTensor, what: &str) {
        crate::testing::assert_csf_matches_rebuild(incremental, reference, 3, 0xA11E, what);
    }

    #[test]
    fn incremental_append_equals_rebuild_over_rounds() {
        let mut rng = Rng::new(11);
        let mut reference = CooTensor::rand(9, 8, 5, 0.3, &mut rng);
        let mut csf = CsfTensor::from_coo(reference.clone());
        for round in 0..5 {
            let kb = 1 + round % 3;
            let batch = CooTensor::rand(9, 8, kb, 0.3, &mut rng);
            csf.append_mode3(&batch);
            reference.append_mode3(&batch);
            assert_matches_rebuild(&csf, &reference, &format!("round {round}"));
        }
    }

    /// The in-place splice's structural edge cases, each against the
    /// bit-exact rebuild oracle: a batch whose roots all sort above the
    /// history (early-exit — the prefix never moves), all below (full
    /// memmove), exactly on the old support (every root and fiber shared:
    /// no new slots, only entries), and a single-entry batch.
    #[test]
    fn in_place_splice_handles_extreme_batch_placements() {
        let (ni, nj) = (10usize, 10usize);
        let mut base = CooTensor::new(ni, nj, 2);
        // History occupies mid-range i/j only, so batches can land fully
        // above, fully below, or exactly on its root support in every
        // orientation.
        for (i, j, k, v) in [(4, 4, 0, 1.0), (4, 6, 1, 2.0), (6, 4, 0, 3.0), (6, 6, 1, 4.0)] {
            base.push(i, j, k, v);
        }
        let batches: [(&str, Vec<(usize, usize, f64)>); 4] = [
            ("above", vec![(8, 9, 5.0), (9, 8, 6.0)]),
            ("below", vec![(0, 1, 7.0), (1, 0, 8.0)]),
            ("shared", vec![(4, 4, 9.0), (4, 6, 10.0), (6, 4, 11.0), (6, 6, 12.0)]),
            ("single", vec![(5, 5, 13.0)]),
        ];
        let mut csf = CsfTensor::from_coo(base.clone());
        let mut reference = base;
        for (what, entries) in &batches {
            let mut batch = CooTensor::new(ni, nj, 1);
            for &(i, j, v) in entries {
                batch.push(i, j, 0, v);
            }
            csf.append_mode3(&batch);
            reference.append_mode3(&batch);
            assert_matches_rebuild(&csf, &reference, what);
        }
    }

    #[test]
    fn incremental_append_csf_batch_equals_rebuild() {
        let mut rng = Rng::new(12);
        let mut reference = CooTensor::rand(7, 9, 6, 0.35, &mut rng);
        let mut csf = CsfTensor::from_coo(reference.clone());
        for round in 0..3 {
            let batch = CooTensor::rand(7, 9, 2, 0.35, &mut rng);
            csf.append_mode3_csf(&CsfTensor::from_coo(batch.clone()));
            reference.append_mode3(&batch);
            assert_matches_rebuild(&csf, &reference, &format!("csf-batch round {round}"));
        }
    }

    #[test]
    fn incremental_append_empty_and_into_empty() {
        let mut rng = Rng::new(13);
        // Empty batch (slices with no entries) still grows the extent.
        let mut reference = CooTensor::rand(6, 6, 4, 0.4, &mut rng);
        let mut csf = CsfTensor::from_coo(reference.clone());
        let empty = CooTensor::new(6, 6, 3);
        csf.append_mode3(&empty);
        reference.append_mode3(&empty);
        assert_matches_rebuild(&csf, &reference, "empty batch");
        // Appending into an empty accumulator adopts the batch's trees.
        let mut reference = CooTensor::new(6, 6, 0);
        let mut csf = CsfTensor::from_coo(reference.clone());
        let batch = CooTensor::rand(6, 6, 4, 0.4, &mut rng);
        csf.append_mode3(&batch);
        reference.append_mode3(&batch);
        assert_matches_rebuild(&csf, &reference, "into empty");
        let mut csf2 = CsfTensor::from_coo(CooTensor::new(6, 6, 0));
        csf2.append_mode3_csf(&CsfTensor::from_coo(batch));
        assert_eq!(csf2.to_dense().data(), csf.to_dense().data());
    }

    #[test]
    fn incremental_append_uncoalesced_batch() {
        // Duplicates and cancellations inside the batch coalesce exactly as
        // the old global rebuild did.
        let mut rng = Rng::new(14);
        let mut reference = CooTensor::rand(5, 5, 3, 0.4, &mut rng);
        let mut csf = CsfTensor::from_coo(reference.clone());
        let mut batch = CooTensor::new(5, 5, 2);
        batch.push(1, 2, 0, 2.0);
        batch.push(1, 2, 0, 3.0); // duplicate: sums to 5.0
        batch.push(4, 4, 1, 1.5);
        batch.push(4, 4, 1, -1.5); // cancels: dropped
        batch.push(0, 0, 1, -2.0);
        csf.append_mode3(&batch);
        reference.append_mode3(&batch);
        reference.coalesce();
        assert_matches_rebuild(&csf, &reference, "uncoalesced batch");
        assert_eq!(csf.to_dense().get(1, 2, 3), 5.0);
    }

    #[test]
    fn incremental_append_new_rows_cols_and_single_fiber() {
        // Batch confined to (i, j) pairs the accumulator has never seen —
        // splices brand-new roots and fibers into trees 0/1 — plus a
        // single-fiber batch extending one existing fiber.
        let mut reference = CooTensor::new(8, 8, 2);
        reference.push(0, 0, 0, 1.0);
        reference.push(0, 0, 1, 2.0);
        reference.push(3, 3, 0, -1.0);
        let mut csf = CsfTensor::from_coo(reference.clone());
        let mut fresh = CooTensor::new(8, 8, 1);
        fresh.push(7, 1, 0, 4.0); // new i=7 root, new fiber
        fresh.push(5, 6, 0, -3.0); // new i=5 and j=6
        fresh.push(1, 0, 0, 0.5); // new i=1, existing j=0
        csf.append_mode3(&fresh);
        reference.append_mode3(&fresh);
        assert_matches_rebuild(&csf, &reference, "new rows/cols");
        let mut single = CooTensor::new(8, 8, 3);
        for k in 0..3 {
            single.push(0, 0, k, (k + 1) as f64);
        }
        csf.append_mode3(&single);
        reference.append_mode3(&single);
        assert_matches_rebuild(&csf, &reference, "single fiber");
    }

    #[test]
    #[should_panic(expected = "matching modes 1-2")]
    fn incremental_append_rejects_mode_mismatch() {
        let mut csf = CsfTensor::from_coo(CooTensor::new(4, 4, 2));
        csf.append_mode3(&CooTensor::new(4, 5, 1));
    }

    #[test]
    fn empty_and_degenerate_safe() {
        let empty = CsfTensor::from_coo(CooTensor::new(4, 4, 4));
        assert_eq!(empty.nnz(), 0);
        assert_eq!(empty.norm(), 0.0);
        let a = Matrix::zeros(4, 2);
        for mode in 0..3 {
            assert_eq!(empty.mttkrp(mode, &a, &a, &a).frob_norm(), 0.0);
            assert_eq!(empty.mode_sum_squares(mode), vec![0.0; 4]);
        }
        assert_eq!(empty.inner_with_kruskal(&[1.0, 1.0], &a, &a, &a), 0.0);
        // Single fiber: all entries share (i, j).
        let mut coo = CooTensor::new(3, 3, 5);
        for k in 0..5 {
            coo.push(1, 2, k, (k + 1) as f64);
        }
        let csf = CsfTensor::from_coo(coo.clone());
        let dense = coo.to_dense();
        let mut rng = Rng::new(8);
        let fa = Matrix::rand_gaussian(3, 2, &mut rng);
        let fb = Matrix::rand_gaussian(3, 2, &mut rng);
        let fc = Matrix::rand_gaussian(5, 2, &mut rng);
        for mode in 0..3 {
            assert!(
                csf.mttkrp(mode, &fa, &fb, &fc)
                    .max_abs_diff(&dense.mttkrp(mode, &fa, &fb, &fc))
                    < 1e-10
            );
        }
        assert!(csf.slice_entries(4).len() == 1);
        assert!(CsfTensor::from_coo(CooTensor::new(2, 2, 2)).slice_entries(0).is_empty());
    }

    #[test]
    fn density_reports_fill() {
        let mut coo = CooTensor::new(2, 2, 2);
        coo.push(0, 0, 0, 1.0);
        coo.push(1, 1, 1, 1.0);
        let csf = CsfTensor::from_coo(coo);
        assert!((csf.density() - 0.25).abs() < 1e-12);
    }
}
