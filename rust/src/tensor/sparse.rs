//! Sparse third-order tensor in coordinate (COO) format, struct-of-arrays.
//!
//! The paper's key scalability lever is that SamBaTen "effectively leverages
//! sparsity": every operation here — MTTKRP, MoI, extraction, norms — is
//! `O(nnz)`, never `O(I·J·K)`. The sparse MTTKRP is also the crate's hottest
//! loop on real-world-shaped workloads and is parallelised over nnz chunks
//! with per-thread accumulators (no locks in the inner loop).

use super::{masked_normals_accumulate, masked_normals_prepare, mode_dim, DenseTensor, Tensor3};
use crate::linalg::Matrix;
use crate::util::par::{chunk_ranges, workers_for};
use crate::util::Rng;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Pooled per-worker partial buffers for the parallel MTTKRP. COO entries
/// scatter to overlapping output rows, so parallel workers need private
/// accumulators (unlike CSF, whose root ranges own disjoint row spans) —
/// before this pool every parallel MTTKRP call paid `workers × out_dim × R`
/// fresh allocations. The pool hands shaped, zeroed buffers out per call
/// and takes them back after the reduction, so steady-state sweeps on a
/// long-lived tensor allocate nothing (`bench_micro` asserts it). Growth is
/// monotone and counted, mirroring `cp::AlsWorkspace`.
#[derive(Default)]
struct PartialPool {
    bufs: Mutex<Vec<Matrix>>,
    allocs: AtomicUsize,
}

impl PartialPool {
    /// `n` buffers shaped `rows × cols`, zero-filled; pooled storage is
    /// reused wherever capacity allows. Thread-safe: concurrent callers
    /// each get disjoint buffers (the pool simply grows to the high-water
    /// concurrency).
    fn take(&self, n: usize, rows: usize, cols: usize) -> Vec<Matrix> {
        let mut out = {
            let mut stash = self.bufs.lock().unwrap_or_else(|e| e.into_inner());
            let keep = stash.len().saturating_sub(n);
            stash.split_off(keep)
        };
        for b in &mut out {
            if b.ensure_shape(rows, cols) {
                self.allocs.fetch_add(1, Ordering::Relaxed);
            }
            b.fill(0.0);
        }
        while out.len() < n {
            out.push(Matrix::zeros(rows, cols));
            self.allocs.fetch_add(1, Ordering::Relaxed);
        }
        out
    }

    fn put(&self, bufs: Vec<Matrix>) {
        let mut stash = self.bufs.lock().unwrap_or_else(|e| e.into_inner());
        stash.extend(bufs);
    }
}

/// COO sparse tensor. Indices are `u32` (dimensions beyond 4B indices are
/// out of scope for this testbed) and values `f64`.
#[derive(Default)]
pub struct CooTensor {
    dims: (usize, usize, usize),
    ii: Vec<u32>,
    jj: Vec<u32>,
    kk: Vec<u32>,
    vv: Vec<f64>,
    /// Scratch, not data: pooled parallel-MTTKRP partials. Never cloned,
    /// compared or serialised — a clone starts with an empty pool.
    partials: PartialPool,
}

impl Clone for CooTensor {
    fn clone(&self) -> Self {
        CooTensor {
            dims: self.dims,
            ii: self.ii.clone(),
            jj: self.jj.clone(),
            kk: self.kk.clone(),
            vv: self.vv.clone(),
            partials: PartialPool::default(),
        }
    }
}

impl std::fmt::Debug for CooTensor {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "CooTensor({}x{}x{}, nnz={})",
            self.dims.0,
            self.dims.1,
            self.dims.2,
            self.vv.len()
        )
    }
}

impl CooTensor {
    pub fn new(i: usize, j: usize, k: usize) -> Self {
        CooTensor { dims: (i, j, k), ..Default::default() }
    }

    pub fn with_capacity(i: usize, j: usize, k: usize, cap: usize) -> Self {
        CooTensor {
            dims: (i, j, k),
            ii: Vec::with_capacity(cap),
            jj: Vec::with_capacity(cap),
            kk: Vec::with_capacity(cap),
            vv: Vec::with_capacity(cap),
        }
    }

    /// Push an entry. Duplicate coordinates are allowed and treated as
    /// summing (standard COO semantics); call [`CooTensor::coalesce`] to
    /// merge them physically.
    #[inline]
    pub fn push(&mut self, i: usize, j: usize, k: usize, v: f64) {
        debug_assert!(i < self.dims.0 && j < self.dims.1 && k < self.dims.2);
        if v == 0.0 {
            return;
        }
        self.ii.push(i as u32);
        self.jj.push(j as u32);
        self.kk.push(k as u32);
        self.vv.push(v);
    }

    /// Merge duplicate coordinates (sums values, drops exact zeros).
    pub fn coalesce(&mut self) {
        let n = self.vv.len();
        let mut order: Vec<usize> = (0..n).collect();
        order.sort_unstable_by_key(|&e| (self.kk[e], self.jj[e], self.ii[e]));
        let mut ii = Vec::with_capacity(n);
        let mut jj = Vec::with_capacity(n);
        let mut kk = Vec::with_capacity(n);
        let mut vv = Vec::with_capacity(n);
        for &e in &order {
            let key = (self.ii[e], self.jj[e], self.kk[e]);
            if let (Some(&li), Some(&lj), Some(&lk)) = (ii.last(), jj.last(), kk.last()) {
                if (li, lj, lk) == key {
                    *vv.last_mut().unwrap() += self.vv[e];
                    continue;
                }
            }
            ii.push(key.0);
            jj.push(key.1);
            kk.push(key.2);
            vv.push(self.vv[e]);
        }
        // Drop zeros created by cancellation.
        let keep: Vec<usize> = (0..vv.len()).filter(|&e| vv[e] != 0.0).collect();
        self.ii = keep.iter().map(|&e| ii[e]).collect();
        self.jj = keep.iter().map(|&e| jj[e]).collect();
        self.kk = keep.iter().map(|&e| kk[e]).collect();
        self.vv = keep.iter().map(|&e| vv[e]).collect();
    }

    /// Entry iterator `(i, j, k, v)`.
    pub fn iter(&self) -> impl Iterator<Item = (usize, usize, usize, f64)> + '_ {
        (0..self.vv.len()).map(move |e| {
            (self.ii[e] as usize, self.jj[e] as usize, self.kk[e] as usize, self.vv[e])
        })
    }

    /// Borrowed struct-of-arrays view `(ii, jj, kk, vv)`. This is how the
    /// CSF backend reads a batch to build its per-mode sorted runs without
    /// an entry-by-entry `iter`/`push` round trip.
    pub(crate) fn raw_parts(&self) -> (&[u32], &[u32], &[u32], &[f64]) {
        (&self.ii, &self.jj, &self.kk, &self.vv)
    }

    pub fn values(&self) -> &[f64] {
        &self.vv
    }

    /// Random sparse tensor with the given fill fraction — test helper.
    pub fn rand(i: usize, j: usize, k: usize, density: f64, rng: &mut Rng) -> Self {
        let total = ((i * j * k) as f64 * density).round() as usize;
        let mut t = CooTensor::with_capacity(i, j, k, total);
        for _ in 0..total {
            t.push(rng.below(i), rng.below(j), rng.below(k), rng.gaussian());
        }
        t.coalesce();
        t
    }

    pub fn from_dense(d: &DenseTensor, threshold: f64) -> Self {
        let (ni, nj, nk) = d.dims();
        let mut t = CooTensor::new(ni, nj, nk);
        for k in 0..nk {
            for j in 0..nj {
                for i in 0..ni {
                    let v = d.get(i, j, k);
                    if v.abs() > threshold {
                        t.push(i, j, k, v);
                    }
                }
            }
        }
        t
    }

    pub fn to_dense(&self) -> DenseTensor {
        let (ni, nj, nk) = self.dims;
        let mut d = DenseTensor::zeros(ni, nj, nk);
        for (i, j, k, v) in self.iter() {
            d.add_at(i, j, k, v);
        }
        d
    }

    /// Extract the sub-tensor at the given index lists. `O(nnz + dims)`:
    /// builds inverse maps, then filters entries.
    pub fn extract(&self, is: &[usize], js: &[usize], ks: &[usize]) -> CooTensor {
        let inv_i = inverse_map(self.dims.0, is);
        let inv_j = inverse_map(self.dims.1, js);
        let inv_k = inverse_map(self.dims.2, ks);
        let mut out = CooTensor::new(is.len(), js.len(), ks.len());
        for e in 0..self.vv.len() {
            let (Some(ni), Some(nj), Some(nk)) = (
                inv_i[self.ii[e] as usize],
                inv_j[self.jj[e] as usize],
                inv_k[self.kk[e] as usize],
            ) else {
                continue;
            };
            out.ii.push(ni);
            out.jj.push(nj);
            out.kk.push(nk);
            out.vv.push(self.vv[e]);
        }
        out
    }

    /// Split along mode 3 at `at` (entries partitioned by `k < at`).
    pub fn split_mode3(&self, at: usize) -> (CooTensor, CooTensor) {
        assert!(at <= self.dims.2);
        let mut a = CooTensor::new(self.dims.0, self.dims.1, at);
        let mut b = CooTensor::new(self.dims.0, self.dims.1, self.dims.2 - at);
        for (i, j, k, v) in self.iter() {
            if k < at {
                a.push(i, j, k, v);
            } else {
                b.push(i, j, k - at, v);
            }
        }
        (a, b)
    }

    /// Append `other` along mode 3 (its `k` indices are shifted by our `K`;
    /// the shift is checked against the `u32` index space — see
    /// [`mode3_shift`]).
    pub fn append_mode3(&mut self, other: &CooTensor) {
        assert_eq!((self.dims.0, self.dims.1), (other.dims.0, other.dims.1));
        let shift = mode3_shift(self.dims.2, other.dims.2);
        self.ii.extend_from_slice(&other.ii);
        self.jj.extend_from_slice(&other.jj);
        // `k + shift < k_old + k_new ≤ u32::MAX` is guaranteed by
        // `mode3_shift`, so the per-entry addition cannot wrap.
        self.kk.extend(other.kk.iter().map(|&k| k + shift));
        self.vv.extend_from_slice(&other.vv);
        self.dims.2 += other.dims.2;
    }

    pub fn norm_sq(&self) -> f64 {
        self.vv.iter().map(|v| v * v).sum()
    }

    /// Density in `[0, 1]`.
    pub fn density(&self) -> f64 {
        let total = self.dims.0 * self.dims.1 * self.dims.2;
        if total == 0 {
            0.0
        } else {
            self.vv.len() as f64 / total as f64
        }
    }

    /// Partial-buffer allocations/growths since construction (the parallel
    /// MTTKRP's pooled per-worker accumulators). Steady-state sweeps at a
    /// fixed shape report zero growth between calls — the COO counterpart
    /// of `AlsWorkspace::allocations`, asserted in `bench_micro`.
    pub fn partial_allocations(&self) -> usize {
        self.partials.allocs.load(Ordering::Relaxed)
    }
}

impl CooTensor {
    /// nnz-range MTTKRP with a compile-time rank (vectorisable inner loop).
    #[inline]
    fn mttkrp_range_const<const R: usize>(
        &self,
        mode: usize,
        a: &Matrix,
        b: &Matrix,
        c: &Matrix,
        range: std::ops::Range<usize>,
        local: &mut Matrix,
    ) {
        for e in range {
            let (i, j, k) = (self.ii[e] as usize, self.jj[e] as usize, self.kk[e] as usize);
            let v = self.vv[e];
            let (dst, f1, f2) = match mode {
                0 => (i, b.row(j), c.row(k)),
                1 => (j, a.row(i), c.row(k)),
                2 => (k, a.row(i), b.row(j)),
                _ => panic!("mode {mode} out of range"),
            };
            let o = local.row_mut(dst);
            for t in 0..R {
                o[t] += v * f1[t] * f2[t];
            }
        }
    }

    fn mttkrp_range_generic(
        &self,
        mode: usize,
        a: &Matrix,
        b: &Matrix,
        c: &Matrix,
        range: std::ops::Range<usize>,
        local: &mut Matrix,
    ) {
        let r = a.cols();
        for e in range {
            let (i, j, k) = (self.ii[e] as usize, self.jj[e] as usize, self.kk[e] as usize);
            let v = self.vv[e];
            let (dst, f1, f2) = match mode {
                0 => (i, b.row(j), c.row(k)),
                1 => (j, a.row(i), c.row(k)),
                2 => (k, a.row(i), b.row(j)),
                _ => panic!("mode {mode} out of range"),
            };
            let o = local.row_mut(dst);
            for t in 0..r {
                o[t] += v * f1[t] * f2[t];
            }
        }
    }
}

/// Checked mode-3 k-shift for appends: growing a `k_old`-deep tensor by
/// `k_new` slices must keep every shifted index inside the `u32` space the
/// sparse backends store (shared by the COO and CSF append paths).
pub(crate) fn mode3_shift(k_old: usize, k_new: usize) -> u32 {
    let end = k_old as u64 + k_new as u64;
    assert!(
        end <= u32::MAX as u64,
        "mode-3 append would grow the tensor to {end} slices, past the u32 \
         index space of the sparse backends ({k_old} existing + {k_new} new)"
    );
    k_old as u32
}

/// Old-index → new-position map for extraction (shared with the CSF
/// backend's fiber-tree walk).
pub(crate) fn inverse_map(dim: usize, idx: &[usize]) -> Vec<Option<u32>> {
    let mut inv = vec![None; dim];
    for (new, &old) in idx.iter().enumerate() {
        inv[old] = Some(new as u32);
    }
    inv
}

impl Tensor3 for CooTensor {
    fn dims(&self) -> (usize, usize, usize) {
        self.dims
    }

    fn norm(&self) -> f64 {
        self.norm_sq().sqrt()
    }

    fn nnz(&self) -> usize {
        self.vv.len()
    }

    fn mttkrp_into(&self, mode: usize, a: &Matrix, b: &Matrix, c: &Matrix, out: &mut Matrix) {
        let r = a.cols();
        debug_assert_eq!(b.cols(), r);
        debug_assert_eq!(c.cols(), r);
        let out_dim = mode_dim(self.dims, mode);
        assert_eq!(
            (out.rows(), out.cols()),
            (out_dim, r),
            "mttkrp_into out-buffer shape mismatch"
        );
        out.fill(0.0);
        let nnz = self.vv.len();
        let nw = workers_for(nnz / 4096 + 1);
        // The inner rank loop is monomorphised for the common ranks.
        let acc_fn = |range: std::ops::Range<usize>, local: &mut Matrix| match r {
            1 => self.mttkrp_range_const::<1>(mode, a, b, c, range, local),
            2 => self.mttkrp_range_const::<2>(mode, a, b, c, range, local),
            3 => self.mttkrp_range_const::<3>(mode, a, b, c, range, local),
            4 => self.mttkrp_range_const::<4>(mode, a, b, c, range, local),
            5 => self.mttkrp_range_const::<5>(mode, a, b, c, range, local),
            6 => self.mttkrp_range_const::<6>(mode, a, b, c, range, local),
            8 => self.mttkrp_range_const::<8>(mode, a, b, c, range, local),
            10 => self.mttkrp_range_const::<10>(mode, a, b, c, range, local),
            16 => self.mttkrp_range_const::<16>(mode, a, b, c, range, local),
            _ => self.mttkrp_range_generic(mode, a, b, c, range, local),
        };
        if nw <= 1 {
            // Serial path (every sample-ALS sweep on summary-sized
            // tensors): scatter straight into the caller's buffer —
            // allocation-free.
            acc_fn(0..nnz, out);
            return;
        }
        // Parallel path: COO entries scatter to overlapping output rows, so
        // workers still need per-thread accumulators (unlike CSF, whose
        // root ranges own disjoint rows). The accumulators come from the
        // per-tensor pool — worker `w` owns slot `w`, uncontended — and go
        // back after the in-place reduction, so a long-lived tensor's
        // steady-state sweeps allocate nothing.
        let ranges = chunk_ranges(nnz, nw);
        let slots: Vec<Mutex<Matrix>> = self
            .partials
            .take(ranges.len(), out_dim, r)
            .into_iter()
            .map(Mutex::new)
            .collect();
        crate::util::parallel_for_each(ranges.len(), |w| {
            let mut local = slots[w].lock().unwrap_or_else(|e| e.into_inner());
            acc_fn(ranges[w].clone(), &mut local);
        });
        let mut bufs = Vec::with_capacity(slots.len());
        for slot in slots {
            let local = slot.into_inner().unwrap_or_else(|e| e.into_inner());
            out.add_in_place(&local);
            bufs.push(local);
        }
        self.partials.put(bufs);
    }

    fn mode_sum_squares(&self, mode: usize) -> Vec<f64> {
        let mut out = vec![0.0; mode_dim(self.dims, mode)];
        for e in 0..self.vv.len() {
            let d = match mode {
                0 => self.ii[e],
                1 => self.jj[e],
                2 => self.kk[e],
                _ => panic!("mode {mode} out of range"),
            } as usize;
            out[d] += self.vv[e] * self.vv[e];
        }
        out
    }

    fn inner_with_kruskal(&self, lambda: &[f64], a: &Matrix, b: &Matrix, c: &Matrix) -> f64 {
        let r = lambda.len();
        let mut acc = 0.0;
        for (i, j, k, v) in self.iter() {
            let (ar, br, cr) = (a.row(i), b.row(j), c.row(k));
            let mut m = 0.0;
            for t in 0..r {
                m += lambda[t] * ar[t] * br[t] * cr[t];
            }
            acc += v * m;
        }
        acc
    }

    fn masked_normals_into(
        &self,
        mode: usize,
        a: &Matrix,
        b: &Matrix,
        c: &Matrix,
        rhs: &mut Matrix,
        grams: &mut Matrix,
    ) {
        let r = a.cols();
        masked_normals_prepare(self.dims, mode, r, rhs, grams);
        // Serial entry scan (the mttkrp_range pattern): observation sets
        // are batch-scale, not history-scale, so the per-row gram
        // accumulation dominates the entry walk and parallel partials
        // would have to replicate the `dim·R×R` gram stack per worker.
        let mut w = vec![0.0f64; r];
        for e in 0..self.vv.len() {
            let (i, j, k) = (self.ii[e] as usize, self.jj[e] as usize, self.kk[e] as usize);
            let (dst, f1, f2) = match mode {
                0 => (i, b.row(j), c.row(k)),
                1 => (j, a.row(i), c.row(k)),
                2 => (k, a.row(i), b.row(j)),
                _ => panic!("mode {mode} out of range"),
            };
            for t in 0..r {
                w[t] = f1[t] * f2[t];
            }
            masked_normals_accumulate(rhs, grams, dst, self.vv[e], &w);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_iter_roundtrip() {
        let mut t = CooTensor::new(3, 3, 3);
        t.push(0, 1, 2, 5.0);
        t.push(2, 2, 2, -1.0);
        t.push(1, 1, 1, 0.0); // dropped
        assert_eq!(t.nnz(), 2);
        let entries: Vec<_> = t.iter().collect();
        assert_eq!(entries[0], (0, 1, 2, 5.0));
        assert_eq!(entries[1], (2, 2, 2, -1.0));
    }

    #[test]
    fn coalesce_merges_duplicates_and_drops_cancels() {
        let mut t = CooTensor::new(2, 2, 2);
        t.push(0, 0, 0, 1.0);
        t.push(0, 0, 0, 2.0);
        t.push(1, 1, 1, 3.0);
        t.push(1, 1, 1, -3.0);
        t.coalesce();
        assert_eq!(t.nnz(), 1);
        assert_eq!(t.iter().next().unwrap(), (0, 0, 0, 3.0));
    }

    #[test]
    fn dense_roundtrip() {
        let mut rng = Rng::new(1);
        let t = CooTensor::rand(5, 6, 7, 0.1, &mut rng);
        let d = t.to_dense();
        let t2 = CooTensor::from_dense(&d, 0.0);
        assert_eq!(t.nnz(), t2.nnz());
        assert!((t.norm() - t2.norm()).abs() < 1e-12);
    }

    #[test]
    fn mttkrp_matches_dense() {
        let mut rng = Rng::new(2);
        let t = CooTensor::rand(6, 5, 4, 0.3, &mut rng);
        let d = t.to_dense();
        let a = Matrix::rand_gaussian(6, 3, &mut rng);
        let b = Matrix::rand_gaussian(5, 3, &mut rng);
        let c = Matrix::rand_gaussian(4, 3, &mut rng);
        for mode in 0..3 {
            let ms = t.mttkrp(mode, &a, &b, &c);
            let md = d.mttkrp(mode, &a, &b, &c);
            assert!(ms.max_abs_diff(&md) < 1e-10, "mode {mode}");
        }
    }

    #[test]
    fn mttkrp_parallel_matches_serial_large() {
        // Enough nnz to trigger the parallel path.
        let mut rng = Rng::new(3);
        let t = CooTensor::rand(40, 40, 40, 0.5, &mut rng);
        assert!(t.nnz() > 8192);
        let a = Matrix::rand_gaussian(40, 4, &mut rng);
        let b = Matrix::rand_gaussian(40, 4, &mut rng);
        let c = Matrix::rand_gaussian(40, 4, &mut rng);
        let par = t.mttkrp(0, &a, &b, &c);
        let ser = t.to_dense().mttkrp(0, &a, &b, &c);
        assert!(par.max_abs_diff(&ser) < 1e-9);
    }

    #[test]
    fn parallel_mttkrp_pools_partial_buffers() {
        let mut rng = Rng::new(9);
        let t = CooTensor::rand(40, 40, 40, 0.5, &mut rng);
        assert!(t.nnz() > 8192, "need the parallel path");
        let a = Matrix::rand_gaussian(40, 4, &mut rng);
        let b = Matrix::rand_gaussian(40, 4, &mut rng);
        let c = Matrix::rand_gaussian(40, 4, &mut rng);
        // Warm the pool across all three modes (same out shape here).
        for mode in 0..3 {
            let _ = t.mttkrp(mode, &a, &b, &c);
        }
        let warm = t.partial_allocations();
        // (On a single-core runner the serial path allocates nothing and
        // `warm` is 0 — the steady-state assertion below still holds.)
        let reference = t.mttkrp(0, &a, &b, &c);
        for _ in 0..3 {
            for mode in 0..3 {
                let _ = t.mttkrp(mode, &a, &b, &c);
            }
        }
        assert_eq!(
            t.partial_allocations(),
            warm,
            "steady-state parallel MTTKRP must reuse pooled partials"
        );
        // Reuse does not change results (buffers are re-zeroed on take).
        assert_eq!(t.mttkrp(0, &a, &b, &c).max_abs_diff(&reference), 0.0);
        // A clone starts with a fresh, empty pool.
        assert_eq!(t.clone().partial_allocations(), 0);
    }

    #[test]
    fn extract_matches_dense_extract() {
        let mut rng = Rng::new(4);
        let t = CooTensor::rand(8, 7, 6, 0.4, &mut rng);
        let is = vec![0, 3, 5];
        let js = vec![6, 2];
        let ks = vec![1, 4, 5];
        let se = t.extract(&is, &js, &ks).to_dense();
        let de = t.to_dense().extract(&is, &js, &ks);
        assert_eq!(se.dims(), de.dims());
        let (ni, nj, nk) = se.dims();
        for i in 0..ni {
            for j in 0..nj {
                for k in 0..nk {
                    assert_eq!(se.get(i, j, k), de.get(i, j, k));
                }
            }
        }
    }

    #[test]
    fn split_append_roundtrip() {
        let mut rng = Rng::new(5);
        let t = CooTensor::rand(5, 5, 10, 0.3, &mut rng);
        let (mut a, b) = t.split_mode3(4);
        assert_eq!(a.dims().2, 4);
        assert_eq!(b.dims().2, 6);
        a.append_mode3(&b);
        assert_eq!(a.dims(), t.dims());
        assert!((a.norm() - t.norm()).abs() < 1e-12);
        // Entry-level equality through dense.
        let d1 = a.to_dense();
        let d2 = t.to_dense();
        assert_eq!(d1.data(), d2.data());
    }

    #[test]
    #[should_panic(expected = "past the u32 index space")]
    fn append_mode3_rejects_u32_overflow() {
        // Dims alone don't allocate, so the overflow guard is testable at
        // the real boundary: u32::MAX existing slices + 1 must refuse.
        let mut t = CooTensor::new(1, 1, u32::MAX as usize);
        let b = CooTensor::new(1, 1, 1);
        t.append_mode3(&b);
    }

    #[test]
    fn mode_sum_squares_matches_dense() {
        let mut rng = Rng::new(6);
        let t = CooTensor::rand(6, 5, 4, 0.5, &mut rng);
        let d = t.to_dense();
        for mode in 0..3 {
            let s = t.mode_sum_squares(mode);
            let e = d.mode_sum_squares(mode);
            for (a, b) in s.iter().zip(&e) {
                assert!((a - b).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn density_reports_fill() {
        let mut t = CooTensor::new(2, 2, 2);
        t.push(0, 0, 0, 1.0);
        t.push(1, 1, 1, 1.0);
        assert!((t.density() - 0.25).abs() < 1e-12);
    }

    #[test]
    fn inner_with_kruskal_matches_dense() {
        let mut rng = Rng::new(7);
        let t = CooTensor::rand(5, 4, 3, 0.5, &mut rng);
        let a = Matrix::rand_gaussian(5, 2, &mut rng);
        let b = Matrix::rand_gaussian(4, 2, &mut rng);
        let c = Matrix::rand_gaussian(3, 2, &mut rng);
        let lam = vec![1.1, 0.4];
        let got = t.inner_with_kruskal(&lam, &a, &b, &c);
        let expect = t.to_dense().inner_with_kruskal(&lam, &a, &b, &c);
        assert!((got - expect).abs() < 1e-9);
    }

    #[test]
    fn empty_tensor_safe() {
        let t = CooTensor::new(3, 3, 3);
        assert_eq!(t.nnz(), 0);
        assert_eq!(t.norm(), 0.0);
        let a = Matrix::zeros(3, 2);
        let m = t.mttkrp(0, &a, &a, &a);
        assert_eq!(m.frob_norm(), 0.0);
    }
}
