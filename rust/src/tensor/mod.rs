//! Third-order tensor substrate: dense and sparse (COO) storage, unfoldings,
//! MTTKRP, mode-wise sums of squares (the paper's Measure of Importance),
//! sub-tensor extraction for sampling, and mode-3 splitting/appending for the
//! incremental setting.
//!
//! The paper (and this reproduction) works with three-mode tensors
//! throughout; the problem definition extends to higher orders, and the
//! module keeps mode-generic signatures (`mode: usize`) so a higher-order
//! extension stays mechanical.

pub mod dense;
pub mod sparse;

pub use dense::DenseTensor;
pub use sparse::CooTensor;

use crate::linalg::Matrix;

/// Uniform interface over dense and sparse tensors — everything CP-ALS and
/// the SamBaTen engine need from the data.
pub trait Tensor3 {
    /// `(I, J, K)`.
    fn dims(&self) -> (usize, usize, usize);

    /// Frobenius norm.
    fn norm(&self) -> f64;

    /// Number of explicitly stored entries.
    fn nnz(&self) -> usize;

    /// Matricized-tensor times Khatri-Rao product for `mode ∈ {0,1,2}`:
    /// `mode 0 → X_(1)(C ⊙ B)`, `mode 1 → X_(2)(C ⊙ A)`, `mode 2 → X_(3)(B ⊙ A)`.
    fn mttkrp(&self, mode: usize, a: &Matrix, b: &Matrix, c: &Matrix) -> Matrix;

    /// Per-index sum of squares along `mode` (Eq. 1 of the paper — the
    /// Measure of Importance used as the sampling weight).
    fn mode_sum_squares(&self, mode: usize) -> Vec<f64>;

    /// Inner product `⟨X, [[λ; A, B, C]]⟩` with a Kruskal model — used for
    /// fit computation without materialising the reconstruction.
    fn inner_with_kruskal(&self, lambda: &[f64], a: &Matrix, b: &Matrix, c: &Matrix) -> f64;
}

/// Owned dense-or-sparse tensor used by engine APIs.
#[derive(Clone, Debug)]
pub enum TensorData {
    Dense(DenseTensor),
    Sparse(CooTensor),
}

impl From<DenseTensor> for TensorData {
    fn from(t: DenseTensor) -> Self {
        TensorData::Dense(t)
    }
}

impl From<CooTensor> for TensorData {
    fn from(t: CooTensor) -> Self {
        TensorData::Sparse(t)
    }
}

impl TensorData {
    pub fn is_sparse(&self) -> bool {
        matches!(self, TensorData::Sparse(_))
    }

    /// Extract the sub-tensor at the given (sorted or unsorted) index sets.
    pub fn extract(&self, is: &[usize], js: &[usize], ks: &[usize]) -> TensorData {
        match self {
            TensorData::Dense(t) => TensorData::Dense(t.extract(is, js, ks)),
            TensorData::Sparse(t) => TensorData::Sparse(t.extract(is, js, ks)),
        }
    }

    /// Concatenate `other` after `self` along mode 3.
    pub fn append_mode3(&mut self, other: &TensorData) {
        match (self, other) {
            (TensorData::Dense(a), TensorData::Dense(b)) => a.append_mode3(b),
            (TensorData::Sparse(a), TensorData::Sparse(b)) => a.append_mode3(b),
            (TensorData::Dense(a), TensorData::Sparse(b)) => a.append_mode3(&b.to_dense()),
            (TensorData::Sparse(a), TensorData::Dense(b)) => {
                a.append_mode3(&CooTensor::from_dense(b, 0.0))
            }
        }
    }

    pub fn to_dense(&self) -> DenseTensor {
        match self {
            TensorData::Dense(t) => t.clone(),
            TensorData::Sparse(t) => t.to_dense(),
        }
    }
}

impl Tensor3 for TensorData {
    fn dims(&self) -> (usize, usize, usize) {
        match self {
            TensorData::Dense(t) => t.dims(),
            TensorData::Sparse(t) => t.dims(),
        }
    }
    fn norm(&self) -> f64 {
        match self {
            TensorData::Dense(t) => t.norm(),
            TensorData::Sparse(t) => t.norm(),
        }
    }
    fn nnz(&self) -> usize {
        match self {
            TensorData::Dense(t) => t.nnz(),
            TensorData::Sparse(t) => t.nnz(),
        }
    }
    fn mttkrp(&self, mode: usize, a: &Matrix, b: &Matrix, c: &Matrix) -> Matrix {
        match self {
            TensorData::Dense(t) => t.mttkrp(mode, a, b, c),
            TensorData::Sparse(t) => t.mttkrp(mode, a, b, c),
        }
    }
    fn mode_sum_squares(&self, mode: usize) -> Vec<f64> {
        match self {
            TensorData::Dense(t) => t.mode_sum_squares(mode),
            TensorData::Sparse(t) => t.mode_sum_squares(mode),
        }
    }
    fn inner_with_kruskal(&self, lambda: &[f64], a: &Matrix, b: &Matrix, c: &Matrix) -> f64 {
        match self {
            TensorData::Dense(t) => t.inner_with_kruskal(lambda, a, b, c),
            TensorData::Sparse(t) => t.inner_with_kruskal(lambda, a, b, c),
        }
    }
}

/// Dimension of `dims` along `mode`.
pub(crate) fn mode_dim(dims: (usize, usize, usize), mode: usize) -> usize {
    match mode {
        0 => dims.0,
        1 => dims.1,
        2 => dims.2,
        _ => panic!("mode {mode} out of range for a 3-mode tensor"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn tensordata_dispatch_consistency() {
        let mut rng = Rng::new(1);
        let mut dense = DenseTensor::zeros(4, 5, 6);
        for _ in 0..30 {
            let (i, j, k) = (rng.below(4), rng.below(5), rng.below(6));
            dense.set(i, j, k, rng.gaussian());
        }
        let coo = CooTensor::from_dense(&dense, 0.0);
        let td: TensorData = dense.clone().into();
        let ts: TensorData = coo.into();
        assert_eq!(td.dims(), ts.dims());
        assert!((td.norm() - ts.norm()).abs() < 1e-12);
        let a = Matrix::rand_gaussian(4, 3, &mut rng);
        let b = Matrix::rand_gaussian(5, 3, &mut rng);
        let c = Matrix::rand_gaussian(6, 3, &mut rng);
        for mode in 0..3 {
            let md = td.mttkrp(mode, &a, &b, &c);
            let ms = ts.mttkrp(mode, &a, &b, &c);
            assert!(md.max_abs_diff(&ms) < 1e-10, "mode {mode}");
            let sd = td.mode_sum_squares(mode);
            let ss = ts.mode_sum_squares(mode);
            for (x, y) in sd.iter().zip(&ss) {
                assert!((x - y).abs() < 1e-12);
            }
        }
        let lam = vec![1.0, 0.5, 2.0];
        let ipd = td.inner_with_kruskal(&lam, &a, &b, &c);
        let ips = ts.inner_with_kruskal(&lam, &a, &b, &c);
        assert!((ipd - ips).abs() < 1e-9);
    }

    #[test]
    fn mixed_append_mode3() {
        let mut rng = Rng::new(2);
        let d1 = DenseTensor::rand(3, 3, 2, &mut rng);
        let d2 = DenseTensor::rand(3, 3, 1, &mut rng);
        let mut td: TensorData = d1.clone().into();
        td.append_mode3(&TensorData::Sparse(CooTensor::from_dense(&d2, 0.0)));
        assert_eq!(td.dims(), (3, 3, 3));
        let got = td.to_dense();
        assert_eq!(got.get(1, 2, 2), d2.get(1, 2, 0));
    }
}
