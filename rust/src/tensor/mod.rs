//! Third-order tensor substrate: dense, sparse-COO and sparse-CSF storage,
//! unfoldings, MTTKRP, mode-wise sums of squares (the paper's Measure of
//! Importance), sub-tensor extraction for sampling, and mode-3
//! splitting/appending for the incremental setting. See DESIGN.md §2 for
//! the backend matrix and the automatic COO→CSF promotion policy.
//!
//! The paper (and this reproduction) works with three-mode tensors
//! throughout; the problem definition extends to higher orders, and the
//! module keeps mode-generic signatures (`mode: usize`) so a higher-order
//! extension stays mechanical.

pub mod csf;
pub mod dense;
pub mod sparse;

pub use csf::CsfTensor;
pub use dense::DenseTensor;
pub use sparse::CooTensor;

use crate::linalg::Matrix;

/// Uniform interface over dense and sparse tensors — everything CP-ALS and
/// the SamBaTen engine need from the data.
pub trait Tensor3 {
    /// `(I, J, K)`.
    fn dims(&self) -> (usize, usize, usize);

    /// Frobenius norm.
    fn norm(&self) -> f64;

    /// Number of explicitly stored entries.
    fn nnz(&self) -> usize;

    /// Matricized-tensor times Khatri-Rao product for `mode ∈ {0,1,2}`
    /// into a caller-owned buffer: `mode 0 → X_(1)(C ⊙ B)`,
    /// `mode 1 → X_(2)(C ⊙ A)`, `mode 2 → X_(3)(B ⊙ A)`.
    ///
    /// `out` must be pre-shaped `mode_dim × R` and is **fully overwritten**
    /// — dirty contents from a previous sweep are fine; the result is
    /// bit-identical to a write into a fresh zeroed buffer. This is the
    /// primitive every backend implements natively; the allocating
    /// [`Tensor3::mttkrp`] is a thin wrapper over it, so workspace-reusing
    /// callers (the ALS sweep loop) and one-shot callers share one kernel.
    fn mttkrp_into(&self, mode: usize, a: &Matrix, b: &Matrix, c: &Matrix, out: &mut Matrix);

    /// Allocating [`Tensor3::mttkrp_into`]: returns a fresh `mode_dim × R`
    /// result matrix.
    fn mttkrp(&self, mode: usize, a: &Matrix, b: &Matrix, c: &Matrix) -> Matrix {
        let r = match mode {
            0 => b.cols(),
            1 | 2 => a.cols(),
            _ => panic!("mode {mode} out of range for a 3-mode tensor"),
        };
        let mut out = Matrix::zeros(mode_dim(self.dims(), mode), r);
        self.mttkrp_into(mode, a, b, c, &mut out);
        out
    }

    /// Per-index sum of squares along `mode` (Eq. 1 of the paper — the
    /// Measure of Importance used as the sampling weight).
    fn mode_sum_squares(&self, mode: usize) -> Vec<f64>;

    /// Inner product `⟨X, [[λ; A, B, C]]⟩` with a Kruskal model — used for
    /// fit computation without materialising the reconstruction.
    fn inner_with_kruskal(&self, lambda: &[f64], a: &Matrix, b: &Matrix, c: &Matrix) -> f64;

    /// Masked per-row normal equations for `mode`, treating this tensor's
    /// stored entries as the observed support `Ω` of an underlying tensor
    /// — the completion setting (DESIGN.md §12). For each mode-`mode`
    /// index `d`, accumulated over the observed cells of its slab, with
    /// `w = f1 ∘ f2` the Khatri-Rao row of the two off-mode factors
    /// (`mode 0: w = B[j] ∘ C[k]`, etc.):
    ///
    /// * `rhs[d, :] = Σ_Ω v · w` — the mask-aware MTTKRP (identical to
    ///   [`Tensor3::mttkrp_into`] restricted to the same entries);
    /// * `grams` rows `d·R .. d·R+R` hold `Σ_Ω w · wᵀ` — the per-row
    ///   normal matrix. A fully observed tensor shares one normal matrix
    ///   across all rows (`⊛_{m≠n} FᵀF`); a masked solve must restrict it
    ///   per row, which is exactly what makes completion a different
    ///   kernel rather than a reweighted MTTKRP.
    ///
    /// `rhs` must be pre-shaped `mode_dim × R` and `grams`
    /// `(mode_dim·R) × R` (row-major: block `d` occupies rows
    /// `d·R..d·R+R`); both are fully overwritten. A dense tensor treats
    /// **every** cell — zeros included — as observed.
    fn masked_normals_into(
        &self,
        mode: usize,
        a: &Matrix,
        b: &Matrix,
        c: &Matrix,
        rhs: &mut Matrix,
        grams: &mut Matrix,
    );
}

/// Owned tensor used by engine APIs: dense, flat sparse (COO) or
/// fiber-tree sparse (CSF — see [`csf`] for when each is chosen).
#[derive(Clone, Debug)]
pub enum TensorData {
    Dense(DenseTensor),
    Sparse(CooTensor),
    Csf(CsfTensor),
}

impl From<DenseTensor> for TensorData {
    fn from(t: DenseTensor) -> Self {
        TensorData::Dense(t)
    }
}

impl From<CooTensor> for TensorData {
    fn from(t: CooTensor) -> Self {
        TensorData::Sparse(t)
    }
}

impl From<CsfTensor> for TensorData {
    fn from(t: CsfTensor) -> Self {
        TensorData::Csf(t)
    }
}

/// nnz threshold above which sparse data is promoted COO → CSF. Below it
/// the fiber-tree build (a sort per mode) costs more than the MTTKRP sweeps
/// it accelerates; above it the sweeps dominate every ingest. Promotion
/// happens at engine init, after each mode-3 append, and when the streaming
/// [`crate::streaming::Batcher`] emits a large batch. The bar is one-way:
/// crossing it promotes once, and falling back below never demotes (see
/// [`TensorData::maybe_promote`]).
pub const CSF_PROMOTION_NNZ: usize = 16_384;

/// Estimated-nnz bar above which [`TensorData::extract`] on a CSF source
/// emits CSF directly instead of COO. Same break-even as the promotion bar:
/// below it the per-orientation tree build costs more than the sample-ALS
/// MTTKRPs it accelerates; above it the `3 · iters` sweeps dominate.
pub const CSF_EXTRACT_NNZ: usize = CSF_PROMOTION_NNZ;

impl TensorData {
    /// True for both sparse representations (COO and CSF).
    pub fn is_sparse(&self) -> bool {
        matches!(self, TensorData::Sparse(_) | TensorData::Csf(_))
    }

    pub fn is_csf(&self) -> bool {
        matches!(self, TensorData::Csf(_))
    }

    /// Promote COO → CSF when nnz is past [`CSF_PROMOTION_NNZ`]. Dense and
    /// already-promoted tensors pass through unchanged.
    pub fn promoted(mut self) -> TensorData {
        self.maybe_promote();
        self
    }

    /// [`TensorData::promoted`] against a caller-chosen bar (see
    /// [`maybe_promote_at`](Self::maybe_promote_at)).
    pub fn promoted_at(mut self, bar: usize) -> TensorData {
        self.maybe_promote_at(bar);
        self
    }

    /// In-place [`TensorData::promoted`] at the default bar.
    ///
    /// The policy is deliberately **one-way** (hysteresis): a COO tensor
    /// promotes the moment its nnz reaches the bar, and a CSF tensor never
    /// demotes — even if later splits or sparse windows drop its nnz back
    /// below the bar, it keeps its fiber trees (mode-3 appends grow them
    /// incrementally). A stream oscillating around the threshold therefore
    /// pays the tree build exactly once instead of thrashing between
    /// rebuilds and demotions.
    pub fn maybe_promote(&mut self) {
        self.maybe_promote_at(CSF_PROMOTION_NNZ);
    }

    /// [`TensorData::maybe_promote`] against a caller-chosen bar — the
    /// per-shape break-even differs (shallow-mode tensors rebuild cheaper),
    /// so the engine exposes it as a `SamBaTenConfig` knob
    /// (`csf_nnz_bar`) instead of hard-wiring the global constant. A bar
    /// of 0 is treated as 1 (an empty tensor never promotes).
    pub fn maybe_promote_at(&mut self, bar: usize) {
        if let TensorData::Sparse(s) = self {
            if s.nnz() >= bar.max(1) {
                *self = TensorData::Csf(CsfTensor::from_coo(std::mem::take(s)));
            }
        }
        // All other variants (Dense, and Csf regardless of nnz) pass
        // through untouched — demotion is never performed.
    }

    /// Extract the sub-tensor at the given (sorted or unsorted) index sets.
    ///
    /// A CSF source walks its fiber trees (skipping unsampled subtrees)
    /// either way; the *output* format depends on the expected size. Most
    /// samples are summary-sized (`dims/s` per mode) and emit COO, but a
    /// large sample (small `s`) whose estimated nnz crosses
    /// [`CSF_EXTRACT_NNZ`] emits CSF directly ([`CsfTensor::extract_csf`])
    /// so its entire sample-ALS runs on the fiber-tree kernels instead of
    /// the COO entry scan — with no COO round trip and no re-sort, because
    /// sorted index sets preserve each orientation's entry order.
    pub fn extract(&self, is: &[usize], js: &[usize], ks: &[usize]) -> TensorData {
        self.extract_with_bar(is, js, ks, CSF_EXTRACT_NNZ)
    }

    /// [`TensorData::extract`] with a caller-chosen CSF-output bar (the
    /// engine threads its `csf_nnz_bar` knob through here via
    /// `SamplerConfig`); a bar of 0 is treated as 1.
    pub fn extract_with_bar(
        &self,
        is: &[usize],
        js: &[usize],
        ks: &[usize],
        bar: usize,
    ) -> TensorData {
        match self {
            TensorData::Dense(t) => TensorData::Dense(t.extract(is, js, ks)),
            TensorData::Sparse(t) => TensorData::Sparse(t.extract(is, js, ks)),
            TensorData::Csf(t) => {
                // Expected extracted nnz under index-independent fill: the
                // kept fraction per mode, applied to the source nnz. MoI-
                // biased samples keep high-energy indices, so this under-
                // estimates — a conservative bar (only clearly-large
                // samples pay the CSF build).
                let (ni, nj, nk) = t.dims();
                let frac = |kept: usize, dim: usize| {
                    if dim == 0 {
                        0.0
                    } else {
                        kept as f64 / dim as f64
                    }
                };
                let est = t.nnz() as f64
                    * frac(is.len(), ni)
                    * frac(js.len(), nj)
                    * frac(ks.len(), nk);
                if est >= bar.max(1) as f64 {
                    TensorData::Csf(t.extract_csf(is, js, ks))
                } else {
                    TensorData::Sparse(t.extract(is, js, ks))
                }
            }
        }
    }

    /// Concatenate `other` after `self` along mode 3. No arm ever
    /// materializes the *accumulator* in another format — conversions are
    /// confined to the (batch-sized) right-hand side, and a CSF batch
    /// merges tree-to-tree into a CSF accumulator with no COO round trip.
    pub fn append_mode3(&mut self, other: &TensorData) {
        match (self, other) {
            (TensorData::Dense(a), TensorData::Dense(b)) => a.append_mode3(b),
            (TensorData::Dense(a), TensorData::Sparse(b)) => a.append_mode3(&b.to_dense()),
            (TensorData::Dense(a), TensorData::Csf(b)) => a.append_mode3(&b.to_dense()),
            (TensorData::Sparse(a), TensorData::Sparse(b)) => a.append_mode3(b),
            (TensorData::Sparse(a), TensorData::Dense(b)) => {
                a.append_mode3(&CooTensor::from_dense(b, 0.0))
            }
            (TensorData::Sparse(a), TensorData::Csf(b)) => a.append_mode3(&b.to_coo()),
            (TensorData::Csf(a), TensorData::Sparse(b)) => a.append_mode3(b),
            (TensorData::Csf(a), TensorData::Dense(b)) => {
                a.append_mode3(&CooTensor::from_dense(b, 0.0))
            }
            (TensorData::Csf(a), TensorData::Csf(b)) => a.append_mode3_csf(b),
        }
    }

    pub fn to_dense(&self) -> DenseTensor {
        match self {
            TensorData::Dense(t) => t.clone(),
            TensorData::Sparse(t) => t.to_dense(),
            TensorData::Csf(t) => t.to_dense(),
        }
    }
}

impl Tensor3 for TensorData {
    fn dims(&self) -> (usize, usize, usize) {
        match self {
            TensorData::Dense(t) => t.dims(),
            TensorData::Sparse(t) => t.dims(),
            TensorData::Csf(t) => t.dims(),
        }
    }
    fn norm(&self) -> f64 {
        match self {
            TensorData::Dense(t) => t.norm(),
            TensorData::Sparse(t) => t.norm(),
            TensorData::Csf(t) => t.norm(),
        }
    }
    fn nnz(&self) -> usize {
        match self {
            TensorData::Dense(t) => t.nnz(),
            TensorData::Sparse(t) => t.nnz(),
            TensorData::Csf(t) => t.nnz(),
        }
    }
    fn mttkrp_into(&self, mode: usize, a: &Matrix, b: &Matrix, c: &Matrix, out: &mut Matrix) {
        match self {
            TensorData::Dense(t) => t.mttkrp_into(mode, a, b, c, out),
            TensorData::Sparse(t) => t.mttkrp_into(mode, a, b, c, out),
            TensorData::Csf(t) => t.mttkrp_into(mode, a, b, c, out),
        }
    }
    fn mode_sum_squares(&self, mode: usize) -> Vec<f64> {
        match self {
            TensorData::Dense(t) => t.mode_sum_squares(mode),
            TensorData::Sparse(t) => t.mode_sum_squares(mode),
            TensorData::Csf(t) => t.mode_sum_squares(mode),
        }
    }
    fn inner_with_kruskal(&self, lambda: &[f64], a: &Matrix, b: &Matrix, c: &Matrix) -> f64 {
        match self {
            TensorData::Dense(t) => t.inner_with_kruskal(lambda, a, b, c),
            TensorData::Sparse(t) => t.inner_with_kruskal(lambda, a, b, c),
            TensorData::Csf(t) => t.inner_with_kruskal(lambda, a, b, c),
        }
    }
    fn masked_normals_into(
        &self,
        mode: usize,
        a: &Matrix,
        b: &Matrix,
        c: &Matrix,
        rhs: &mut Matrix,
        grams: &mut Matrix,
    ) {
        match self {
            TensorData::Dense(t) => t.masked_normals_into(mode, a, b, c, rhs, grams),
            TensorData::Sparse(t) => t.masked_normals_into(mode, a, b, c, rhs, grams),
            TensorData::Csf(t) => t.masked_normals_into(mode, a, b, c, rhs, grams),
        }
    }
}

/// Shared prologue of the three `masked_normals_into` kernels: shape-check
/// the caller buffers against `(dims, mode, R)` and zero them.
pub(crate) fn masked_normals_prepare(
    dims: (usize, usize, usize),
    mode: usize,
    r: usize,
    rhs: &mut Matrix,
    grams: &mut Matrix,
) {
    let out_dim = mode_dim(dims, mode);
    assert_eq!(
        (rhs.rows(), rhs.cols()),
        (out_dim, r),
        "masked_normals_into rhs-buffer shape mismatch"
    );
    assert_eq!(
        (grams.rows(), grams.cols()),
        (out_dim * r, r),
        "masked_normals_into grams-buffer shape mismatch"
    );
    rhs.fill(0.0);
    grams.fill(0.0);
}

/// Shared accumulate step of the masked-normals kernels: fold one observed
/// entry with Khatri-Rao row `w` and value `v` into output row `dst` —
/// `rhs[dst] += v·w`, `grams` block `dst` `+= w·wᵀ`.
#[inline]
pub(crate) fn masked_normals_accumulate(
    rhs: &mut Matrix,
    grams: &mut Matrix,
    dst: usize,
    v: f64,
    w: &[f64],
) {
    let r = w.len();
    let o = rhs.row_mut(dst);
    for t in 0..r {
        o[t] += v * w[t];
    }
    let g = &mut grams.data_mut()[dst * r * r..(dst + 1) * r * r];
    for t in 0..r {
        let wt = w[t];
        let grow = &mut g[t * r..(t + 1) * r];
        for (gu, wu) in grow.iter_mut().zip(w) {
            *gu += wt * wu;
        }
    }
}

/// Dimension of `dims` along `mode`.
pub(crate) fn mode_dim(dims: (usize, usize, usize), mode: usize) -> usize {
    match mode {
        0 => dims.0,
        1 => dims.1,
        2 => dims.2,
        _ => panic!("mode {mode} out of range for a 3-mode tensor"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn tensordata_dispatch_consistency() {
        let mut rng = Rng::new(1);
        let mut dense = DenseTensor::zeros(4, 5, 6);
        for _ in 0..30 {
            let (i, j, k) = (rng.below(4), rng.below(5), rng.below(6));
            dense.set(i, j, k, rng.gaussian());
        }
        let coo = CooTensor::from_dense(&dense, 0.0);
        let td: TensorData = dense.clone().into();
        let ts: TensorData = coo.into();
        assert_eq!(td.dims(), ts.dims());
        assert!((td.norm() - ts.norm()).abs() < 1e-12);
        let a = Matrix::rand_gaussian(4, 3, &mut rng);
        let b = Matrix::rand_gaussian(5, 3, &mut rng);
        let c = Matrix::rand_gaussian(6, 3, &mut rng);
        for mode in 0..3 {
            let md = td.mttkrp(mode, &a, &b, &c);
            let ms = ts.mttkrp(mode, &a, &b, &c);
            assert!(md.max_abs_diff(&ms) < 1e-10, "mode {mode}");
            let sd = td.mode_sum_squares(mode);
            let ss = ts.mode_sum_squares(mode);
            for (x, y) in sd.iter().zip(&ss) {
                assert!((x - y).abs() < 1e-12);
            }
        }
        let lam = vec![1.0, 0.5, 2.0];
        let ipd = td.inner_with_kruskal(&lam, &a, &b, &c);
        let ips = ts.inner_with_kruskal(&lam, &a, &b, &c);
        assert!((ipd - ips).abs() < 1e-9);
    }

    #[test]
    fn csf_dispatch_promotion_and_mixed_append() {
        let mut rng = Rng::new(3);
        let coo = CooTensor::rand(6, 5, 4, 0.5, &mut rng);
        let td: TensorData = coo.clone().into();
        let tc: TensorData = CsfTensor::from_coo(coo).into();
        assert!(tc.is_sparse() && tc.is_csf());
        assert_eq!(td.dims(), tc.dims());
        assert!((td.norm() - tc.norm()).abs() < 1e-12);
        let a = Matrix::rand_gaussian(6, 2, &mut rng);
        let b = Matrix::rand_gaussian(5, 2, &mut rng);
        let c = Matrix::rand_gaussian(4, 2, &mut rng);
        for mode in 0..3 {
            let diff = td
                .mttkrp(mode, &a, &b, &c)
                .max_abs_diff(&tc.mttkrp(mode, &a, &b, &c));
            assert!(diff < 1e-10, "mode {mode}: {diff}");
        }
        // Extraction from CSF yields COO (samples are summary-sized).
        let sub = tc.extract(&[0, 2], &[1, 3], &[0, 1, 2]);
        assert!(sub.is_sparse() && !sub.is_csf());
        // CSF accumulators accept COO and dense batches.
        let mut grown = tc.clone();
        grown.append_mode3(&td.extract(&[0, 1, 2, 3, 4, 5], &[0, 1, 2, 3, 4], &[0, 1]));
        assert!(grown.is_csf());
        assert_eq!(grown.dims(), (6, 5, 6));
        grown.append_mode3(&TensorData::Dense(DenseTensor::zeros(6, 5, 1)));
        assert_eq!(grown.dims(), (6, 5, 7));
        // Promotion: below the nnz bar stays COO, above becomes CSF.
        let small: TensorData = CooTensor::rand(5, 5, 5, 0.2, &mut rng).into();
        assert!(!small.promoted().is_csf());
        let big: TensorData = CooTensor::rand(40, 40, 40, 0.5, &mut rng).into();
        assert!(big.nnz() >= CSF_PROMOTION_NNZ, "nnz {}", big.nnz());
        let promoted = big.clone().promoted();
        assert!(promoted.is_csf());
        assert!((promoted.norm() - big.norm()).abs() < 1e-9);
    }

    #[test]
    fn promotion_is_one_way_hysteresis() {
        // A CSF tensor far below the promotion bar stays CSF through every
        // promotion checkpoint: no demotion, so an oscillating stream never
        // re-pays tree builds.
        let mut rng = Rng::new(5);
        let small = CooTensor::rand(6, 6, 6, 0.2, &mut rng);
        assert!(small.nnz() < CSF_PROMOTION_NNZ);
        let mut t = TensorData::Csf(CsfTensor::from_coo(small));
        t.maybe_promote();
        assert!(t.is_csf(), "maybe_promote must not demote");
        assert!(t.clone().promoted().is_csf());
        // Growth keeps the variant too: appends merge into the trees
        // in place rather than dropping back to COO.
        let batch: TensorData = CooTensor::rand(6, 6, 2, 0.2, &mut rng).into();
        t.append_mode3(&batch);
        t.maybe_promote();
        assert!(t.is_csf());
        assert_eq!(t.dims(), (6, 6, 8));
    }

    #[test]
    fn promotion_and_extraction_bars_are_configurable() {
        let mut rng = Rng::new(9);
        let small = CooTensor::rand(6, 6, 6, 0.3, &mut rng);
        let nnz = small.nnz();
        assert!(nnz > 1 && nnz < CSF_PROMOTION_NNZ);
        // A lowered bar promotes what the default bar keeps COO.
        let t: TensorData = small.clone().into();
        assert!(!t.clone().promoted().is_csf());
        assert!(t.clone().promoted_at(nnz).is_csf());
        assert!(!t.clone().promoted_at(nnz + 1).is_csf());
        // Bar 0 is clamped to 1: an empty tensor still never promotes.
        let empty: TensorData = CooTensor::new(4, 4, 4).into();
        assert!(!empty.promoted_at(0).is_csf());
        // Extraction output format follows the bar the same way, with
        // identical content either side of it.
        let csf = TensorData::Csf(CsfTensor::from_coo(small));
        let is: Vec<usize> = (0..6).collect();
        let sub_default = csf.extract(&is, &is, &is);
        assert!(!sub_default.is_csf(), "below the default bar extraction emits COO");
        let sub_low = csf.extract_with_bar(&is, &is, &is, 1);
        assert!(sub_low.is_csf(), "a lowered bar emits CSF");
        assert_eq!(sub_default.to_dense().data(), sub_low.to_dense().data());
    }

    #[test]
    fn csf_csf_append_merges_without_coo_roundtrip() {
        let mut rng = Rng::new(6);
        let base = CooTensor::rand(7, 6, 5, 0.4, &mut rng);
        let batch = CooTensor::rand(7, 6, 3, 0.4, &mut rng);
        let mut via_csf = TensorData::Csf(CsfTensor::from_coo(base.clone()));
        via_csf.append_mode3(&TensorData::Csf(CsfTensor::from_coo(batch.clone())));
        assert!(via_csf.is_csf());
        let mut want = base;
        want.append_mode3(&batch);
        assert_eq!(via_csf.dims(), want.dims());
        assert_eq!(via_csf.to_dense().data(), want.to_dense().data());
    }

    #[test]
    fn masked_normals_agree_across_backends_and_match_mttkrp() {
        let mut rng = Rng::new(11);
        let coo = CooTensor::rand(6, 5, 4, 0.4, &mut rng);
        let csf = CsfTensor::from_coo(coo.clone());
        let r = 3;
        let a = Matrix::rand_gaussian(6, r, &mut rng);
        let b = Matrix::rand_gaussian(5, r, &mut rng);
        let c = Matrix::rand_gaussian(4, r, &mut rng);
        for mode in 0..3 {
            let dim = mode_dim(coo.dims(), mode);
            let mut rhs_coo = Matrix::zeros(dim, r);
            let mut g_coo = Matrix::zeros(dim * r, r);
            coo.masked_normals_into(mode, &a, &b, &c, &mut rhs_coo, &mut g_coo);
            let mut rhs_csf = Matrix::zeros(dim, r);
            let mut g_csf = Matrix::zeros(dim * r, r);
            csf.masked_normals_into(mode, &a, &b, &c, &mut rhs_csf, &mut g_csf);
            assert!(rhs_coo.max_abs_diff(&rhs_csf) < 1e-10, "mode {mode} rhs");
            assert!(g_coo.max_abs_diff(&g_csf) < 1e-10, "mode {mode} grams");
            // The RHS is exactly the MTTKRP over the stored support.
            assert!(
                rhs_coo.max_abs_diff(&coo.mttkrp(mode, &a, &b, &c)) < 1e-10,
                "mode {mode}: masked rhs must equal the MTTKRP on the same entries"
            );
            // Dirty buffers are fully overwritten.
            rhs_coo.fill(7.0);
            g_coo.fill(-3.0);
            coo.masked_normals_into(mode, &a, &b, &c, &mut rhs_coo, &mut g_coo);
            assert!(rhs_coo.max_abs_diff(&rhs_csf) < 1e-10);
            assert!(g_coo.max_abs_diff(&g_csf) < 1e-10);
        }
    }

    #[test]
    fn fully_observed_masked_grams_collapse_to_the_shared_normal_matrix() {
        // When every cell is observed the per-row masked gram must equal
        // the fully-observed ALS normal matrix ⊛_{m≠n} FᵀF — the masked
        // solve degenerates to the classic sweep.
        let mut rng = Rng::new(13);
        let dense = DenseTensor::rand(4, 3, 5, &mut rng);
        let coo = CooTensor::from_dense(&dense, -1.0); // gaussian: no zeros
        assert_eq!(coo.nnz(), 4 * 3 * 5);
        let r = 2;
        let a = Matrix::rand_gaussian(4, r, &mut rng);
        let b = Matrix::rand_gaussian(3, r, &mut rng);
        let c = Matrix::rand_gaussian(5, r, &mut rng);
        let shared = [
            b.gram().hadamard(&c.gram()),
            a.gram().hadamard(&c.gram()),
            a.gram().hadamard(&b.gram()),
        ];
        for mode in 0..3 {
            let dim = mode_dim(dense.dims(), mode);
            for t in [
                TensorData::Dense(dense.clone()),
                TensorData::Sparse(coo.clone()),
            ] {
                let mut rhs = Matrix::zeros(dim, r);
                let mut grams = Matrix::zeros(dim * r, r);
                t.masked_normals_into(mode, &a, &b, &c, &mut rhs, &mut grams);
                for d in 0..dim {
                    for p in 0..r {
                        for q in 0..r {
                            let got = grams[(d * r + p, q)];
                            let want = shared[mode][(p, q)];
                            assert!(
                                (got - want).abs() < 1e-9,
                                "mode {mode} row {d}: {got} vs {want}"
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn mixed_append_mode3() {
        let mut rng = Rng::new(2);
        let d1 = DenseTensor::rand(3, 3, 2, &mut rng);
        let d2 = DenseTensor::rand(3, 3, 1, &mut rng);
        let mut td: TensorData = d1.clone().into();
        td.append_mode3(&TensorData::Sparse(CooTensor::from_dense(&d2, 0.0)));
        assert_eq!(td.dims(), (3, 3, 3));
        let got = td.to_dense();
        assert_eq!(got.get(1, 2, 2), d2.get(1, 2, 0));
    }
}
