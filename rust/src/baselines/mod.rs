//! The four baselines of §IV-C, re-implemented from their source papers'
//! update equations (the original code drops are Matlab):
//!
//! * [`CpAlsFull`] — re-run `CP_ALS` from scratch on every update (the
//!   non-incremental reference).
//! * [`OnlineCp`] — Zhou et al., KDD 2016: auxiliary `P`/`Q` accumulators,
//!   closed-form `C_new`, one-solve updates for `A`, `B`.
//! * [`Sdt`] — Nion & Sidiropoulos, IEEE TSP 2009: incremental SVD tracking
//!   of the mode-3 unfolding + Khatri-Rao structuring of the right factor.
//! * [`Rlst`] — Nion & Sidiropoulos, IEEE TSP 2009: recursive least squares
//!   tracking of `C` and `D = (B ⊙ A)`.
//!
//! All of them share the [`IncrementalDecomposer`] trait with the SamBaTen
//! engine wrapper so the evaluation harness treats every method uniformly.
//! Note all four baselines operate on **dense unfoldings** — exactly like
//! the paper's baselines, which is why they stop scaling while SamBaTen
//! keeps going (Tables IV-VI).

pub mod cpals_full;
pub mod onlinecp;
pub mod rlst;
pub mod sdt;

pub use cpals_full::CpAlsFull;
pub use onlinecp::OnlineCp;
pub use rlst::Rlst;
pub use sdt::Sdt;

use crate::cp::CpModel;
use crate::tensor::TensorData;
use anyhow::Result;

/// A method that maintains a CP decomposition of a tensor growing in mode 3.
pub trait IncrementalDecomposer: Send {
    /// Method name as reported in tables.
    fn name(&self) -> &'static str;

    /// Ingest a batch of new slices.
    fn ingest(&mut self, x_new: &TensorData) -> Result<()>;

    /// Current model estimate.
    fn model(&self) -> CpModel;

    /// Whether the method exploits sparsity (only SamBaTen and — partially —
    /// repeated CP_ALS do; see §IV-D.1).
    fn exploits_sparsity(&self) -> bool {
        false
    }
}

/// Wrapper making the SamBaTen engine an [`IncrementalDecomposer`] so the
/// harness can run it side by side with the baselines.
pub struct SamBaTenMethod(pub crate::coordinator::SamBaTen);

impl IncrementalDecomposer for SamBaTenMethod {
    fn name(&self) -> &'static str {
        "SamBaTen"
    }
    fn ingest(&mut self, x_new: &TensorData) -> Result<()> {
        self.0.ingest(x_new).map(|_| ())
    }
    fn model(&self) -> CpModel {
        self.0.model().clone()
    }
    fn exploits_sparsity(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{SamBaTen, SamBaTenConfig};
    use crate::datagen::SyntheticSpec;
    use crate::metrics::relative_error;

    /// Every method, fed the same stream, must track the tensor reasonably.
    #[test]
    fn all_methods_track_a_clean_low_rank_stream() {
        let spec = SyntheticSpec::dense(12, 12, 16, 2, 0.01, 21);
        let (existing, batches, _) = spec.generate_stream(0.4, 4);
        let (full, _) = spec.generate();
        let mut methods: Vec<Box<dyn IncrementalDecomposer>> = vec![
            Box::new(CpAlsFull::init(&existing, 2, 11).unwrap()),
            Box::new(OnlineCp::init(&existing, 2, 12).unwrap()),
            Box::new(Sdt::init(&existing, 2, 13).unwrap()),
            Box::new(Rlst::init(&existing, 2, 14).unwrap()),
            Box::new(SamBaTenMethod(
                SamBaTen::init(&existing, SamBaTenConfig::builder(2, 2, 4, 15).build().unwrap())
                    .unwrap(),
            )),
        ];
        for m in &mut methods {
            for b in &batches {
                m.ingest(b).unwrap();
            }
            let re = relative_error(&full, &m.model());
            let bound = match m.name() {
                // Tracking methods are less accurate — the paper observes
                // the same (SDT/RLST roughly half the fitness of others).
                "SDT" | "RLST" => 0.75,
                _ => 0.4,
            };
            assert!(re < bound, "{}: relative error {re}", m.name());
            assert_eq!(m.model().factors[2].rows(), 16, "{}", m.name());
        }
    }
}
