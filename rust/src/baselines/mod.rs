//! The four baselines of §IV-C, re-implemented from their source papers'
//! update equations (the original code drops are Matlab):
//!
//! * [`CpAlsFull`] — re-run `CP_ALS` from scratch on every update (the
//!   non-incremental reference).
//! * [`OnlineCp`] — Zhou et al., KDD 2016: auxiliary `P`/`Q` accumulators,
//!   closed-form `C_new`, one-solve updates for `A`, `B`.
//! * [`Sdt`] — Nion & Sidiropoulos, IEEE TSP 2009: incremental SVD tracking
//!   of the mode-3 unfolding + Khatri-Rao structuring of the right factor.
//! * [`Rlst`] — Nion & Sidiropoulos, IEEE TSP 2009: recursive least squares
//!   tracking of `C` and `D = (B ⊙ A)`.
//!
//! All of them share the [`IncrementalDecomposer`] trait with the
//! coordinator engines (via [`EngineMethod`], which adapts any
//! [`crate::coordinator::DecompositionEngine`]) so the evaluation harness
//! treats every method uniformly.
//! Note all four baselines operate on **dense unfoldings** — exactly like
//! the paper's baselines, which is why they stop scaling while SamBaTen
//! keeps going (Tables IV-VI).

pub mod cpals_full;
pub mod onlinecp;
pub mod rlst;
pub mod sdt;

pub use cpals_full::CpAlsFull;
pub use onlinecp::OnlineCp;
pub use rlst::Rlst;
pub use sdt::Sdt;

use crate::cp::CpModel;
use crate::tensor::TensorData;
use anyhow::Result;

/// A method that maintains a CP decomposition of a tensor growing in mode 3.
pub trait IncrementalDecomposer: Send {
    /// Method name as reported in tables.
    fn name(&self) -> &'static str;

    /// Ingest a batch of new slices.
    fn ingest(&mut self, x_new: &TensorData) -> Result<()>;

    /// Current model estimate.
    fn model(&self) -> CpModel;

    /// Whether the method exploits sparsity (only SamBaTen and — partially —
    /// repeated CP_ALS do; see §IV-D.1).
    fn exploits_sparsity(&self) -> bool {
        false
    }
}

/// Wrapper adapting any [`crate::coordinator::DecompositionEngine`]
/// (SamBaTen, OCTen, whatever comes next) to the baseline trait, so the
/// harness runs coordinator engines side by side with the baselines. It
/// carries the table display name ("SamBaTen", "OCTen") separately —
/// engines self-report lowercase CLI identifiers.
pub struct EngineMethod {
    name: &'static str,
    engine: Box<dyn crate::coordinator::DecompositionEngine>,
}

impl EngineMethod {
    pub fn new(
        name: &'static str,
        engine: Box<dyn crate::coordinator::DecompositionEngine>,
    ) -> Self {
        EngineMethod { name, engine }
    }
}

impl IncrementalDecomposer for EngineMethod {
    fn name(&self) -> &'static str {
        self.name
    }
    fn ingest(&mut self, x_new: &TensorData) -> Result<()> {
        self.engine.ingest(x_new).map(|_| ())
    }
    fn model(&self) -> CpModel {
        self.engine.model().clone()
    }
    fn exploits_sparsity(&self) -> bool {
        self.engine.exploits_sparsity()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{OcTen, OcTenConfig, SamBaTen, SamBaTenConfig};
    use crate::datagen::SyntheticSpec;
    use crate::metrics::relative_error;

    /// Every method, fed the same stream, must track the tensor reasonably.
    #[test]
    fn all_methods_track_a_clean_low_rank_stream() {
        let spec = SyntheticSpec::dense(12, 12, 16, 2, 0.01, 21);
        let (existing, batches, _) = spec.generate_stream(0.4, 4);
        let (full, _) = spec.generate();
        let mut methods: Vec<Box<dyn IncrementalDecomposer>> = vec![
            Box::new(CpAlsFull::init(&existing, 2, 11).unwrap()),
            Box::new(OnlineCp::init(&existing, 2, 12).unwrap()),
            Box::new(Sdt::init(&existing, 2, 13).unwrap()),
            Box::new(Rlst::init(&existing, 2, 14).unwrap()),
            Box::new(EngineMethod::new(
                "SamBaTen",
                Box::new(
                    SamBaTen::init(
                        &existing,
                        SamBaTenConfig::builder(2, 2, 4, 15).build().unwrap(),
                    )
                    .unwrap(),
                ),
            )),
            Box::new(EngineMethod::new(
                "OCTen",
                Box::new(
                    OcTen::init(&existing, OcTenConfig::builder(2, 4, 2, 16).build().unwrap())
                        .unwrap(),
                ),
            )),
        ];
        for m in &mut methods {
            for b in &batches {
                m.ingest(b).unwrap();
            }
            let re = relative_error(&full, &m.model());
            let bound = match m.name() {
                // Tracking methods are less accurate — the paper observes
                // the same (SDT/RLST roughly half the fitness of others).
                // OCTen trades accuracy for compressed-space updates.
                "SDT" | "RLST" => 0.75,
                "OCTen" => 0.6,
                _ => 0.4,
            };
            assert!(re < bound, "{}: relative error {re}", m.name());
            assert_eq!(m.model().factors[2].rows(), 16, "{}", m.name());
        }
    }
}
