//! OnlineCP (Zhou, Vinh, Bailey, Jia, Davidson — KDD 2016).
//!
//! Keeps complementary matrices so no pass over old data is ever needed:
//! for each non-temporal mode `n ∈ {1,2}` it accumulates
//! `P_n = X_(n) · KR_n` and `Q_n = ⊛_{m≠n} F_mᵀF_m`. A new batch yields
//!
//! 1. `C_new = X_new(3) (B ⊙ A) [(AᵀA) ∘ (BᵀB)]⁻¹` (closed-form LS),
//! 2. `P₁ += X_new(1) (C_new ⊙ B)`, `Q₁ += (C_newᵀC_new) ∘ (BᵀB)`,
//!    `A = P₁ Q₁⁻¹` (and symmetrically for `B`),
//! 3. `C ← [C; C_new]`.
//!
//! Everything is dense (`IJ`-sized products), which is precisely why the
//! method stops scaling in the paper's large configurations.

use super::IncrementalDecomposer;
use crate::cp::{cp_als, AlsOptions, CpModel};
use crate::linalg::{solve_gram_system, Matrix};
use crate::tensor::{Tensor3, TensorData};
use anyhow::Result;

pub struct OnlineCp {
    a: Matrix,
    b: Matrix,
    c: Matrix,
    /// P/Q accumulators for modes 1 and 2.
    p1: Matrix,
    q1: Matrix,
    p2: Matrix,
    q2: Matrix,
}

impl OnlineCp {
    pub fn init(x_old: &TensorData, rank: usize, seed: u64) -> Result<Self> {
        let opts = AlsOptions { seed, ..Default::default() };
        let (mut model, _) = cp_als(x_old, rank, &opts)?;
        // Work with unnormalised factors (λ absorbed into C, the growing mode).
        for t in 0..rank {
            model.factors[2].scale_col(t, model.lambda[t]);
            model.lambda[t] = 1.0;
        }
        let [a, b, c] = model.factors;
        // Initial accumulators from the historical tensor (one-time cost).
        let p1 = x_old.mttkrp(0, &a, &b, &c);
        let p2 = x_old.mttkrp(1, &a, &b, &c);
        let q1 = b.gram().hadamard(&c.gram());
        let q2 = a.gram().hadamard(&c.gram());
        Ok(OnlineCp { a, b, c, p1, q1, p2, q2 })
    }
}

impl IncrementalDecomposer for OnlineCp {
    fn name(&self) -> &'static str {
        "OnlineCP"
    }

    fn ingest(&mut self, x_new: &TensorData) -> Result<()> {
        let r = self.a.cols();
        // Fidelity note: the published OnlineCP (like SDT/RLST) computes on
        // dense unfoldings — "no baselines except CP_ALS actually take
        // advantage of that sparsity" (§IV-D.1). Densify the batch so the
        // cost model matches the paper's.
        let x_new = &TensorData::Dense(x_new.to_dense());
        // 1. C_new via closed-form LS with A, B fixed.
        let m3 = x_new.mttkrp(2, &self.a, &self.b, &self.c); // C arg unused for mode 2
        let g3 = self.a.gram().hadamard(&self.b.gram());
        let c_new = solve_gram_system(&g3, &m3)?;
        // 2. Mode-1 update.
        let m1 = x_new.mttkrp(0, &self.a, &self.b, &c_new);
        self.p1 = self.p1.add(&m1);
        self.q1 = self.q1.add(&c_new.gram().hadamard(&self.b.gram()));
        self.a = solve_gram_system(&self.q1, &self.p1)?;
        // Mode-2 update (uses the *updated* A, per the OnlineCP paper).
        let m2 = x_new.mttkrp(1, &self.a, &self.b, &c_new);
        self.p2 = self.p2.add(&m2);
        self.q2 = self.q2.add(&c_new.gram().hadamard(&self.a.gram()));
        self.b = solve_gram_system(&self.q2, &self.p2)?;
        // 3. Append.
        self.c = self.c.vstack(&c_new);
        debug_assert_eq!(self.c.cols(), r);
        Ok(())
    }

    fn model(&self) -> CpModel {
        let r = self.a.cols();
        let mut m =
            CpModel::new(self.a.clone(), self.b.clone(), self.c.clone(), vec![1.0; r]);
        m.normalize();
        m.sort_components();
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen::SyntheticSpec;
    use crate::metrics::relative_error;

    #[test]
    fn tracks_clean_stream_closely() {
        let spec = SyntheticSpec::dense(10, 10, 16, 2, 0.0, 5);
        let (existing, batches, _) = spec.generate_stream(0.4, 4);
        let (full, _) = spec.generate();
        let mut m = OnlineCp::init(&existing, 2, 6).unwrap();
        for b in &batches {
            m.ingest(b).unwrap();
        }
        let re = relative_error(&full, &m.model());
        assert!(re < 0.15, "relative error {re}");
    }

    #[test]
    fn c_grows_by_batch_size() {
        let spec = SyntheticSpec::dense(8, 8, 12, 2, 0.0, 7);
        let (existing, batches, _) = spec.generate_stream(0.5, 2);
        let mut m = OnlineCp::init(&existing, 2, 8).unwrap();
        assert_eq!(m.c.rows(), 6);
        m.ingest(&batches[0]).unwrap();
        assert_eq!(m.c.rows(), 8);
    }

    #[test]
    fn sparse_input_accepted_but_densified_cost() {
        // OnlineCP accepts sparse TensorData (MTTKRP handles it) — the
        // asymptotic win of SamBaTen is elsewhere (summary-space ALS).
        let spec = SyntheticSpec::sparse(8, 8, 10, 2, 0.6, 0.0, 9);
        let (existing, batches, _) = spec.generate_stream(0.5, 5);
        let mut m = OnlineCp::init(&existing, 2, 10).unwrap();
        m.ingest(&batches[0]).unwrap();
        assert_eq!(m.c.rows(), 10);
    }
}
