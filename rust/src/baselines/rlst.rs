//! RLST — Recursive Least Squares Tracking (Nion & Sidiropoulos, IEEE TSP
//! 2009), reconstructed from the published update equations: per batch,
//!
//! 1. `C_new = X_new(3) · D (DᵀD)⁻¹` — LS fit of the new slices against the
//!    tracked Khatri-Rao factor `D = (B ⊙ A)` (the paper's
//!    `C_new = X_new D_old†`),
//! 2. RLS update of `D`: with inverse covariance `P = (Σ CᵀC)⁻¹` maintained
//!    by the matrix-inversion lemma (forgetting factor 1),
//!    `D ← D + (X_new(3)ᵀ − D C_newᵀ) C_new P` ("D is estimated using matrix
//!    inversion on X_new and C_new"),
//! 3. `C ← [C; C_new]`; `A`, `B` recovered from rank-1 reshapes of `D`'s
//!    columns.

use super::IncrementalDecomposer;
use crate::cp::{cp_als, AlsOptions, CpModel};
use crate::linalg::{pinv, solve_gram_system, svd_truncated, Matrix};
use crate::tensor::{Tensor3, TensorData};
use anyhow::Result;

pub struct Rlst {
    ni: usize,
    nj: usize,
    rank: usize,
    /// Tracked Khatri-Rao factor `D = B ⊙ A`, IJ × R (unfold-3 column
    /// layout: row `i + I·j`).
    d: Matrix,
    /// Inverse covariance `P = (CᵀC)⁻¹`, R × R.
    p: Matrix,
    c: Matrix,
}

impl Rlst {
    pub fn init(x_old: &TensorData, rank: usize, seed: u64) -> Result<Self> {
        let (ni, nj, _) = x_old.dims();
        let opts = AlsOptions { seed, max_iters: 200, ..Default::default() };
        let (model, _) = cp_als(x_old, rank, &opts)?;
        let mut c = model.factors[2].clone();
        for t in 0..rank {
            c.scale_col(t, model.lambda[t]);
        }
        // D in unfold-3 layout: row (i + I*j) = A(i,:) .* B(j,:).
        let mut d = Matrix::zeros(ni * nj, rank);
        for j in 0..nj {
            for i in 0..ni {
                for t in 0..rank {
                    d[(i + ni * j, t)] = model.factors[0][(i, t)] * model.factors[1][(j, t)];
                }
            }
        }
        let p = pinv(&c.gram(), None);
        Ok(Rlst { ni, nj, rank, d, p, c })
    }

    /// Sherman-Morrison-Woodbury update of `P = (CᵀC)⁻¹` after appending
    /// rows `c_new` (K_new × R):
    /// `P ← P − P C_newᵀ (I + C_new P C_newᵀ)⁻¹ C_new P`.
    fn update_p(&mut self, c_new: &Matrix) -> Result<()> {
        let k_new = c_new.rows();
        let pc = self.p.matmul_t(c_new); // R × K_new
        let mut inner = c_new.matmul(&pc); // K_new × K_new
        for i in 0..k_new {
            inner[(i, i)] += 1.0;
        }
        let inv_inner = pinv(&inner, None);
        let corr = pc.matmul(&inv_inner).matmul(&pc.transpose());
        self.p = self.p.sub(&corr);
        Ok(())
    }

    fn factors_from_d(&self) -> (Matrix, Matrix) {
        let mut a = Matrix::zeros(self.ni, self.rank);
        let mut b = Matrix::zeros(self.nj, self.rank);
        for t in 0..self.rank {
            let mut slab = Matrix::zeros(self.ni, self.nj);
            for j in 0..self.nj {
                for i in 0..self.ni {
                    slab[(i, j)] = self.d[(i + self.ni * j, t)];
                }
            }
            let sv = svd_truncated(&slab, 1);
            let scale = sv.s[0].sqrt();
            for i in 0..self.ni {
                a[(i, t)] = sv.u[(i, 0)] * scale;
            }
            for j in 0..self.nj {
                b[(j, t)] = sv.v[(j, 0)] * scale;
            }
        }
        (a, b)
    }
}

impl IncrementalDecomposer for Rlst {
    fn name(&self) -> &'static str {
        "RLST"
    }

    fn ingest(&mut self, x_new: &TensorData) -> Result<()> {
        let rows = x_new.to_dense().unfold(2); // K_new × IJ
        // 1. C_new = X_new D (DᵀD)⁻¹.
        let xd = rows.matmul(&self.d); // K_new × R
        let g = self.d.gram();
        let c_new = solve_gram_system(&g, &xd)?;
        // 2. RLS update of P then D.
        self.update_p(&c_new)?;
        // Innovation: (X_newᵀ − D C_newᵀ) C_new P.
        let resid = rows.transpose().sub(&self.d.matmul_t(&c_new)); // IJ × K_new
        let gain = c_new.matmul(&self.p); // K_new × R
        self.d = self.d.add(&resid.matmul(&gain));
        // 3. Append.
        self.c = self.c.vstack(&c_new);
        Ok(())
    }

    fn model(&self) -> CpModel {
        let (a, b) = self.factors_from_d();
        let mut m = CpModel::new(a, b, self.c.clone(), vec![1.0; self.rank]);
        m.normalize();
        m.sort_components();
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen::SyntheticSpec;
    use crate::metrics::relative_error;

    #[test]
    fn p_update_matches_direct_inverse() {
        let mut rng = crate::util::Rng::new(1);
        let c0 = Matrix::rand_gaussian(10, 3, &mut rng);
        let c_new = Matrix::rand_gaussian(4, 3, &mut rng);
        let mut r = Rlst {
            ni: 2,
            nj: 2,
            rank: 3,
            d: Matrix::zeros(4, 3),
            p: pinv(&c0.gram(), None),
            c: c0.clone(),
        };
        r.update_p(&c_new).unwrap();
        let full = c0.vstack(&c_new);
        let direct = pinv(&full.gram(), None);
        assert!(r.p.max_abs_diff(&direct) < 1e-8);
    }

    #[test]
    fn tracks_clean_stream() {
        let spec = SyntheticSpec::dense(8, 9, 16, 2, 0.0, 4);
        let (existing, batches, _) = spec.generate_stream(0.5, 4);
        let (full, _) = spec.generate();
        let mut m = Rlst::init(&existing, 2, 5).unwrap();
        for b in &batches {
            m.ingest(b).unwrap();
        }
        let re = relative_error(&full, &m.model());
        assert!(re < 0.5, "relative error {re}");
        assert_eq!(m.model().factors[2].rows(), 16);
    }

    #[test]
    fn c_grows_per_batch() {
        let spec = SyntheticSpec::dense(6, 6, 10, 2, 0.0, 6);
        let (existing, batches, _) = spec.generate_stream(0.5, 5);
        let mut m = Rlst::init(&existing, 2, 7).unwrap();
        m.ingest(&batches[0]).unwrap();
        assert_eq!(m.c.rows(), 10);
    }
}
