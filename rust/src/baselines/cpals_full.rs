//! `CP_ALS` baseline: "simply re-compute CP using CP_ALS every time a new
//! batch update arrives" (§IV-C). The accuracy reference — and the cost
//! reference that motivates incremental methods in the first place.

use super::IncrementalDecomposer;
use crate::cp::{cp_als_with, AlsOptions, AlsWorkspace, CpModel};
use crate::tensor::TensorData;
use anyhow::Result;

pub struct CpAlsFull {
    x: TensorData,
    rank: usize,
    opts: AlsOptions,
    model: CpModel,
    batch_counter: u64,
    /// Reused across every recompute: the workspace grows once to the
    /// largest `(dims, rank)` seen and every later batch's sweeps run
    /// allocation-free.
    ws: AlsWorkspace,
}

impl CpAlsFull {
    pub fn init(x_old: &TensorData, rank: usize, seed: u64) -> Result<Self> {
        Self::init_with(x_old, rank, AlsOptions { seed, ..Default::default() })
    }

    pub fn init_with(x_old: &TensorData, rank: usize, opts: AlsOptions) -> Result<Self> {
        let mut ws = AlsWorkspace::new();
        let (model, _) = cp_als_with(x_old, rank, &opts, &mut ws)?;
        Ok(CpAlsFull { x: x_old.clone(), rank, opts, model, batch_counter: 0, ws })
    }
}

impl IncrementalDecomposer for CpAlsFull {
    fn name(&self) -> &'static str {
        "CP_ALS"
    }

    fn ingest(&mut self, x_new: &TensorData) -> Result<()> {
        self.x.append_mode3(x_new);
        self.batch_counter += 1;
        // Cold restart with a fresh seed per batch — the paper's protocol
        // re-computes the entire decomposition from scratch.
        let opts = AlsOptions {
            seed: self.opts.seed.wrapping_add(self.batch_counter),
            ..self.opts.clone()
        };
        let (model, _) = cp_als_with(&self.x, self.rank, &opts, &mut self.ws)?;
        self.model = model;
        Ok(())
    }

    fn model(&self) -> CpModel {
        self.model.clone()
    }

    fn exploits_sparsity(&self) -> bool {
        // Tensor-Toolbox cp_als exploits sparse MTTKRP; so does ours.
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen::SyntheticSpec;
    use crate::metrics::relative_error;
    use crate::tensor::Tensor3;

    #[test]
    fn recompute_is_near_optimal_each_step() {
        let spec = SyntheticSpec::dense(10, 10, 12, 2, 0.0, 1);
        let (existing, batches, _) = spec.generate_stream(0.5, 3);
        let mut m = CpAlsFull::init(&existing, 2, 3).unwrap();
        let mut acc = existing.clone();
        for b in &batches {
            m.ingest(b).unwrap();
            acc.append_mode3(b);
            let re = relative_error(&acc, &m.model());
            assert!(re < 0.05, "relative error {re}");
        }
    }

    #[test]
    fn tensor_grows_with_batches() {
        let spec = SyntheticSpec::sparse(8, 8, 10, 2, 0.5, 0.0, 2);
        let (existing, batches, _) = spec.generate_stream(0.5, 5);
        let mut m = CpAlsFull::init(&existing, 2, 4).unwrap();
        m.ingest(&batches[0]).unwrap();
        assert_eq!(m.model().factors[2].rows(), 10);
        assert_eq!(m.x.dims().2, 10);
    }
}
