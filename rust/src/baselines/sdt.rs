//! SDT — Simultaneous Diagonalization Tracking (Nion & Sidiropoulos, IEEE
//! TSP 2009), reconstructed from the published update equations:
//!
//! * Track the truncated SVD `X_(3)ᵀ ≈ ... / X_(3) = U Σ Vᵀ` under row
//!   appends with Brand's incremental update (project new rows on `V`,
//!   QR the residual, re-SVD the small core).
//! * Recover the Khatri-Rao structure of the right factor: each column of
//!   `D = V Σ W` must reshape (I×J) to rank one; `W` is refined by
//!   alternating rank-1 structuring, warm-started across batches.
//! * `A`, `B` from the per-column rank-1 SVDs; `C` from the projection of
//!   the tracked subspace onto the structured Khatri-Rao basis.

use super::IncrementalDecomposer;
use crate::cp::{cp_als_with, AlsOptions, AlsWorkspace, CpModel};
use crate::linalg::{
    pinv, qr_thin, solve_gram_system_into, svd_truncated, GramSolveScratch, Matrix,
};
use crate::tensor::{Tensor3, TensorData};
use anyhow::Result;

pub struct Sdt {
    ni: usize,
    nj: usize,
    rank: usize,
    /// Tracked SVD of the K×IJ unfolding: `U` is K×R (grows), `s` length R,
    /// `V` is IJ×R.
    u: Matrix,
    s: Vec<f64>,
    v: Matrix,
    /// Mixing matrix for Khatri-Rao structuring (R×R), warm-started.
    w: Matrix,
    a: Matrix,
    b: Matrix,
    c: Matrix,
    /// Cholesky scratch reused by every per-batch `recompute_c`.
    solve_scratch: GramSolveScratch,
}

impl Sdt {
    pub fn init(x_old: &TensorData, rank: usize, seed: u64) -> Result<Self> {
        let (ni, nj, _) = x_old.dims();
        let unf = x_old.to_dense().unfold(2); // K × IJ
        let svd = svd_truncated(&unf, rank);
        // The existing tensor can have fewer slices than the tracked rank
        // (K < R): pad the tracked SVD with zero components — Brand's
        // update recovers the missing directions from incoming residuals.
        let have = svd.s.len();
        let (u, s, v) = if have < rank {
            let mut u = Matrix::zeros(unf.rows(), rank);
            let mut v = Matrix::zeros(unf.cols(), rank);
            let mut s = vec![0.0; rank];
            for t in 0..have {
                s[t] = svd.s[t];
                for i in 0..unf.rows() {
                    u[(i, t)] = svd.u[(i, t)];
                }
                for i in 0..unf.cols() {
                    v[(i, t)] = svd.v[(i, t)];
                }
            }
            (u, s, v)
        } else {
            (svd.u, svd.s, svd.v)
        };
        let opts = AlsOptions { seed, max_iters: 200, ..Default::default() };
        let (model, _) = cp_als_with(x_old, rank, &opts, &mut AlsWorkspace::new())?;
        let mut sdt = Sdt {
            ni,
            nj,
            rank,
            u,
            s,
            v,
            w: Matrix::identity(rank),
            a: model.factors[0].clone(),
            b: model.factors[1].clone(),
            c: model.factors[2].clone(),
            solve_scratch: GramSolveScratch::new(),
        };
        // Absorb λ into C.
        for t in 0..rank {
            sdt.c.scale_col(t, model.lambda[t]);
        }
        sdt.refine_structure(3);
        Ok(sdt)
    }

    /// Brand row-append update of the tracked SVD with new rows `rows`
    /// (K_new × IJ), truncating back to `rank`.
    fn svd_append_rows(&mut self, rows: &Matrix) {
        let r = self.rank;
        let k_new = rows.rows();
        // Projection onto the current right subspace.
        let p = rows.matmul(&self.v); // K_new × R
        // Residual and its orthonormal complement.
        let e = rows.sub(&p.matmul_t(&self.v)); // K_new × IJ
        let (qe, re) = qr_thin(&e.transpose()); // IJ×K_new, K_new×K_new
        // Core matrix [[diag(s), 0], [P, Reᵀ]].
        let m = r + k_new;
        let mut core = Matrix::zeros(m, m);
        for t in 0..r {
            core[(t, t)] = self.s[t];
        }
        for i in 0..k_new {
            for t in 0..r {
                core[(r + i, t)] = p[(i, t)];
            }
            for t in 0..k_new {
                core[(r + i, r + t)] = re[(t, i)]; // Reᵀ
            }
        }
        let cs = svd_truncated(&core, r);
        // U' = blkdiag(U, I) · cs.u  (rows: K_old + K_new).
        let k_old = self.u.rows();
        let mut u_new = Matrix::zeros(k_old + k_new, r);
        for i in 0..k_old {
            for t in 0..r {
                let mut acc = 0.0;
                for q in 0..r {
                    acc += self.u[(i, q)] * cs.u[(q, t)];
                }
                u_new[(i, t)] = acc;
            }
        }
        for i in 0..k_new {
            for t in 0..r {
                let mut acc = 0.0;
                for q in 0..m {
                    let left = if q < r { 0.0 } else { if q - r == i { 1.0 } else { 0.0 } };
                    acc += left * cs.u[(q, t)];
                }
                u_new[(k_old + i, t)] = acc;
            }
        }
        // V' = [V, Qe] · cs.v.
        let mut v_new = Matrix::zeros(self.v.rows(), r);
        for i in 0..self.v.rows() {
            for t in 0..r {
                let mut acc = 0.0;
                for q in 0..r {
                    acc += self.v[(i, q)] * cs.v[(q, t)];
                }
                for q in 0..k_new {
                    acc += qe[(i, q)] * cs.v[(r + q, t)];
                }
                v_new[(i, t)] = acc;
            }
        }
        self.u = u_new;
        self.v = v_new;
        self.s = cs.s;
    }

    /// Alternating Khatri-Rao structuring: refine `W`, then read `A`, `B`
    /// from per-column rank-1 reshapes of `D = V diag(s) W`.
    fn refine_structure(&mut self, iters: usize) {
        let r = self.rank;
        // VS = V diag(s), IJ×R.
        let mut vs = self.v.clone();
        for t in 0..r {
            vs.scale_col(t, self.s[t]);
        }
        for _ in 0..iters {
            let d = vs.matmul(&self.w); // IJ×R
            // Rank-1 reshape per column (unfold-3 column index = i + I*j).
            let mut kr = Matrix::zeros(self.ni * self.nj, r);
            for t in 0..r {
                let mut slab = Matrix::zeros(self.ni, self.nj);
                for j in 0..self.nj {
                    for i in 0..self.ni {
                        slab[(i, j)] = d[(i + self.ni * j, t)];
                    }
                }
                let sv = svd_truncated(&slab, 1);
                let scale = sv.s[0].sqrt();
                for i in 0..self.ni {
                    self.a[(i, t)] = sv.u[(i, 0)] * scale;
                }
                for j in 0..self.nj {
                    self.b[(j, t)] = sv.v[(j, 0)] * scale;
                }
                for j in 0..self.nj {
                    for i in 0..self.ni {
                        kr[(i + self.ni * j, t)] = self.a[(i, t)] * self.b[(j, t)];
                    }
                }
            }
            // W ← (VS)⁺ · KR keeps D tied to the tracked subspace.
            self.w = pinv(&vs, None).matmul(&kr);
        }
    }

    /// `C = X_(3) (B⊙A) G⁻¹` computed inside the tracked subspace:
    /// `X_(3)(B⊙A) ≈ U diag(s) (Vᵀ KR)`.
    fn recompute_c(&mut self) -> Result<()> {
        let r = self.rank;
        let kr = self.b.khatri_rao(&self.a); // rows j*I+i = b_j .* a_i ✓ unfold-3 cols
        let vt_kr = self.v.t_matmul(&kr); // R × R
        let mut us = self.u.clone();
        for t in 0..r {
            us.scale_col(t, self.s[t]);
        }
        let m = us.matmul(&vt_kr); // K × R
        let g = self.a.gram().hadamard(&self.b.gram());
        // In-place: `c` is reshaped to the grown K and fully overwritten;
        // the Cholesky scratch is reused across batches.
        solve_gram_system_into(&g, &m, &mut self.solve_scratch, &mut self.c)?;
        Ok(())
    }
}

impl IncrementalDecomposer for Sdt {
    fn name(&self) -> &'static str {
        "SDT"
    }

    fn ingest(&mut self, x_new: &TensorData) -> Result<()> {
        let rows = x_new.to_dense().unfold(2); // K_new × IJ
        self.svd_append_rows(&rows);
        self.refine_structure(2);
        self.recompute_c()?;
        Ok(())
    }

    fn model(&self) -> CpModel {
        let r = self.rank;
        let mut m =
            CpModel::new(self.a.clone(), self.b.clone(), self.c.clone(), vec![1.0; r]);
        m.normalize();
        m.sort_components();
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen::SyntheticSpec;
    use crate::metrics::relative_error;

    #[test]
    fn svd_append_matches_full_svd() {
        let mut rng = crate::util::Rng::new(1);
        // Exactly rank-3 matrix: Brand's truncated update is exact only when
        // the discarded subspace carries no energy.
        let left = Matrix::rand_gaussian(12, 3, &mut rng);
        let right = Matrix::rand_gaussian(3, 30, &mut rng);
        let full = left.matmul(&right);
        // Build an Sdt shell tracking rank 3 from the first 8 rows.
        let head = full.gather_rows(&(0..8).collect::<Vec<_>>());
        let tail = full.gather_rows(&(8..12).collect::<Vec<_>>());
        let svd = svd_truncated(&head, 3);
        let mut sdt = Sdt {
            ni: 5,
            nj: 6,
            rank: 3,
            u: svd.u,
            s: svd.s,
            v: svd.v,
            w: Matrix::identity(3),
            a: Matrix::zeros(5, 3),
            b: Matrix::zeros(6, 3),
            c: Matrix::zeros(8, 3),
            solve_scratch: GramSolveScratch::new(),
        };
        sdt.svd_append_rows(&tail);
        let truth = svd_truncated(&full, 3);
        for t in 0..3 {
            assert!(
                (sdt.s[t] - truth.s[t]).abs() / truth.s[t] < 1e-8,
                "σ{t}: {} vs {}",
                sdt.s[t],
                truth.s[t]
            );
        }
        // Reconstruction agreement on the tracked rank.
        let rec = |u: &Matrix, s: &[f64], v: &Matrix| {
            let mut us = u.clone();
            for t in 0..3 {
                us.scale_col(t, s[t]);
            }
            us.matmul_t(v)
        };
        let d = rec(&sdt.u, &sdt.s, &sdt.v).max_abs_diff(&rec(&truth.u, &truth.s, &truth.v));
        assert!(d < 1e-7, "diff {d}");
    }

    #[test]
    fn tracks_clean_stream() {
        let spec = SyntheticSpec::dense(8, 9, 16, 2, 0.0, 2);
        let (existing, batches, _) = spec.generate_stream(0.5, 4);
        let (full, _) = spec.generate();
        let mut m = Sdt::init(&existing, 2, 3).unwrap();
        for b in &batches {
            m.ingest(b).unwrap();
        }
        let re = relative_error(&full, &m.model());
        assert!(re < 0.5, "relative error {re}");
        assert_eq!(m.model().factors[2].rows(), 16);
    }
}
