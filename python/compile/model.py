"""Layer 2 — the JAX compute graph: one full CP-ALS sweep.

``als_sweep`` performs the three mode updates of CP-ALS, each built from the
Layer-1 Pallas MTTKRP kernel plus a ridge-regularised Cholesky-backed solve
of the R×R Gram-Hadamard system. It deliberately does **not** normalise
factor columns: the Rust coordinator runs N sweeps by feeding outputs back
as inputs, then canonicalises (unit columns, weights in λ) once at the end.

Zero-padding contract (what lets fixed AOT shapes serve dynamic samples):
padding X with zero slices/rows and the factors with zero rows keeps every
real row's update bit-identical and padded rows stay exactly zero. Padding
*rank* with zero columns is also safe because the ridge keeps the Gram
system solvable and maps zero MTTKRP columns to zero solution columns.
Property-tested in python/tests/test_model.py and rust runtime tests.
"""

import jax
import jax.numpy as jnp

from compile.kernels.mttkrp import mttkrp

# Ridge scale: relative to mean Gram diagonal, so padded/rank-deficient
# systems stay solvable without perturbing well-conditioned ones noticeably.
EPS = 1e-8


def _inv_spd(g):
    """Inverse of a tiny SPD matrix via unrolled Gauss-Jordan.

    ``jnp.linalg``/``jax.scipy`` solves lower to LAPACK custom-calls on CPU
    (API_VERSION_TYPED_FFI) which the Rust side's xla_extension 0.5.1
    rejects; this unrolled elimination emits pure HLO ops. R ≤ 8 and the
    ridge keeps the system diagonally healthy, so no pivoting is needed.
    """
    r = g.shape[0]
    aug = jnp.concatenate([g, jnp.eye(r, dtype=g.dtype)], axis=1)
    for t in range(r):
        row = aug[t] / aug[t, t]
        aug = aug - jnp.outer(aug[:, t], row)
        aug = aug.at[t].set(row)
    return aug[:, r:]


def _solve(gram, m):
    """Solve F · gram = m row-wise with relative ridge."""
    r = gram.shape[0]
    scale = jnp.trace(gram) / r + 1.0
    reg = gram + EPS * scale * jnp.eye(r, dtype=gram.dtype)
    return m @ _inv_spd(reg)


def als_sweep(x, a, b, c):
    """One CP-ALS sweep over modes 1..3. Returns updated ``(a, b, c)``.

    After the three updates, columns of ``a`` and ``b`` are rebalanced to
    unit norm with the scale absorbed into ``c`` (the cp_als convention).
    Without this, ALS regularly stalls in scaling swamps. Zero columns
    (rank padding) are guarded and stay exactly zero, preserving the
    padding contract.
    """
    m0 = mttkrp(x, a, b, c, 0)
    a = _solve((b.T @ b) * (c.T @ c), m0)
    m1 = mttkrp(x, a, b, c, 1)
    b = _solve((a.T @ a) * (c.T @ c), m1)
    m2 = mttkrp(x, a, b, c, 2)
    c = _solve((a.T @ a) * (b.T @ b), m2)
    na = jnp.linalg.norm(a, axis=0)
    nb = jnp.linalg.norm(b, axis=0)
    sa = jnp.where(na > 0, na, 1.0)
    sb = jnp.where(nb > 0, nb, 1.0)
    a = a / sa
    b = b / sb
    c = c * (sa * sb)
    return a, b, c


def als_sweeps(x, a, b, c, n):
    """``n`` sweeps via lax.fori_loop (single fused HLO; used when the
    caller wants a fixed iteration count baked into one executable)."""

    def body(_, abc):
        return als_sweep(x, *abc)

    return jax.lax.fori_loop(0, n, body, (a, b, c))


def cp_loss(x, a, b, c):
    """Squared Frobenius reconstruction error (diagnostics)."""
    rec = jnp.einsum("ir,jr,kr->ijk", a, b, c)
    d = x - rec
    return jnp.sum(d * d)
