"""Layer 1 — Pallas MTTKRP kernels.

MTTKRP (matricized tensor times Khatri-Rao product) is the compute hot-spot
of CP-ALS: for mode 1, ``M = X_(1) (C ⊙ B)``. The naive formulation
materialises the ``IJ x R`` Khatri-Rao product; these kernels never do —
each grid step contracts one frontal slice ``X[:, :, k]`` against the
factor matrices directly:

* mode 1: ``M += X[:,:,k] @ (B * C[k,:])``          (an I×J · J×R matmul)
* mode 2: ``M += X[:,:,k].T @ (A * C[k,:])``        (a  J×I · I×R matmul)
* mode 3: ``M[k,:] = sum_j (X[:,:,k].T @ A * B)_j`` (matmul + row reduce)

TPU mapping (DESIGN.md §Hardware-Adaptation): the grid iterates over the K
mode so each step holds one I×J slice plus J×R / 1×R factor blocks in VMEM
(BlockSpec expresses the HBM→VMEM schedule), and the contraction is shaped
as a plain matmul so it lands on the MXU with R padded to the lane width.
``interpret=True`` everywhere — the CPU PJRT plugin cannot execute Mosaic
custom-calls; real-TPU performance is estimated analytically in
EXPERIMENTS.md §Perf.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# interpret=True is mandatory on CPU; kept as a module switch so a real-TPU
# build only has to flip it.
INTERPRET = True


def _mttkrp1_kernel(x_ref, b_ref, c_ref, o_ref):
    """Grid step k: o += X[:,:,k] @ (B * C[k,:])."""
    k = pl.program_id(0)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x_k = x_ref[:, :, 0]  # (I, J)
    scaled = b_ref[...] * c_ref[...]  # (J, R) * (1, R): broadcast over rows
    o_ref[...] += jnp.dot(x_k, scaled, preferred_element_type=jnp.float32)


def _mttkrp2_kernel(x_ref, a_ref, c_ref, o_ref):
    """Grid step k: o += X[:,:,k].T @ (A * C[k,:])."""
    k = pl.program_id(0)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x_k = x_ref[:, :, 0]  # (I, J)
    scaled = a_ref[...] * c_ref[...]  # (I, R)
    o_ref[...] += jnp.dot(x_k.T, scaled, preferred_element_type=jnp.float32)


def _mttkrp3_kernel(x_ref, a_ref, b_ref, o_ref):
    """Grid step k: o[0,:] = sum_j ((X[:,:,k].T @ A) * B)[j,:]."""
    x_k = x_ref[:, :, 0]  # (I, J)
    t = jnp.dot(x_k.T, a_ref[...], preferred_element_type=jnp.float32)  # (J, R)
    o_ref[...] = jnp.sum(t * b_ref[...], axis=0, keepdims=True)  # (1, R)


def mttkrp(x, a, b, c, mode):
    """MTTKRP of a dense third-order tensor for ``mode in {0, 1, 2}``.

    ``x``: (I, J, K); ``a``: (I, R); ``b``: (J, R); ``c``: (K, R).
    Returns (dim_mode, R). Factor matrices of the target mode are accepted
    (and ignored) so call sites stay uniform.
    """
    i_dim, j_dim, k_dim = x.shape
    r = a.shape[1]
    if mode == 0:
        return pl.pallas_call(
            _mttkrp1_kernel,
            grid=(k_dim,),
            in_specs=[
                pl.BlockSpec((i_dim, j_dim, 1), lambda k: (0, 0, k)),
                pl.BlockSpec((j_dim, r), lambda k: (0, 0)),
                pl.BlockSpec((1, r), lambda k: (k, 0)),
            ],
            out_specs=pl.BlockSpec((i_dim, r), lambda k: (0, 0)),
            out_shape=jax.ShapeDtypeStruct((i_dim, r), x.dtype),
            interpret=INTERPRET,
        )(x, b, c)
    if mode == 1:
        return pl.pallas_call(
            _mttkrp2_kernel,
            grid=(k_dim,),
            in_specs=[
                pl.BlockSpec((i_dim, j_dim, 1), lambda k: (0, 0, k)),
                pl.BlockSpec((i_dim, r), lambda k: (0, 0)),
                pl.BlockSpec((1, r), lambda k: (k, 0)),
            ],
            out_specs=pl.BlockSpec((j_dim, r), lambda k: (0, 0)),
            out_shape=jax.ShapeDtypeStruct((j_dim, r), x.dtype),
            interpret=INTERPRET,
        )(x, a, c)
    if mode == 2:
        return pl.pallas_call(
            _mttkrp3_kernel,
            grid=(k_dim,),
            in_specs=[
                pl.BlockSpec((i_dim, j_dim, 1), lambda k: (0, 0, k)),
                pl.BlockSpec((i_dim, r), lambda k: (0, 0)),
                pl.BlockSpec((j_dim, r), lambda k: (0, 0)),
            ],
            out_specs=pl.BlockSpec((1, r), lambda k: (k, 0)),
            out_shape=jax.ShapeDtypeStruct((k_dim, r), x.dtype),
            interpret=INTERPRET,
        )(x, a, b)
    raise ValueError(f"mode {mode} out of range for a 3-mode tensor")


mttkrp_jit = jax.jit(partial(mttkrp), static_argnames=("mode",))
