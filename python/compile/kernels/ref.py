"""Pure-jnp oracle for the Pallas kernels (the build-time correctness
signal: pytest asserts kernel == ref to float tolerance).

The reference is the *definitional* einsum — it shares no code path with
the kernels' slice-wise contraction.
"""

import jax.numpy as jnp


def khatri_rao(p, q):
    """Column-wise Kronecker: (P ⊙ Q)[i*Jq + j, r] = P[i,r] * Q[j,r]."""
    ip, r = p.shape
    jq, r2 = q.shape
    assert r == r2
    return (p[:, None, :] * q[None, :, :]).reshape(ip * jq, r)


def mttkrp_ref(x, a, b, c, mode):
    """Definitional MTTKRP: M[d, r] = Σ X(i,j,k) · (other factors)."""
    if mode == 0:
        return jnp.einsum("ijk,jr,kr->ir", x, b, c)
    if mode == 1:
        return jnp.einsum("ijk,ir,kr->jr", x, a, c)
    if mode == 2:
        return jnp.einsum("ijk,ir,jr->kr", x, a, b)
    raise ValueError(mode)


def als_sweep_ref(x, a, b, c, eps=1e-8):
    """Reference ALS sweep (same math as model.als_sweep, no Pallas)."""
    r = a.shape[1]
    eye = jnp.eye(r, dtype=x.dtype)

    def solve(gram, m):
        scale = jnp.trace(gram) / r + 1.0
        return jnp.linalg.solve(gram + eps * scale * eye, m.T).T

    m0 = mttkrp_ref(x, a, b, c, 0)
    a = solve((b.T @ b) * (c.T @ c), m0)
    m1 = mttkrp_ref(x, a, b, c, 1)
    b = solve((a.T @ a) * (c.T @ c), m1)
    m2 = mttkrp_ref(x, a, b, c, 2)
    c = solve((a.T @ a) * (b.T @ b), m2)
    # Same rebalancing convention as model.als_sweep.
    na = jnp.linalg.norm(a, axis=0)
    nb = jnp.linalg.norm(b, axis=0)
    sa = jnp.where(na > 0, na, 1.0)
    sb = jnp.where(nb > 0, nb, 1.0)
    return a / sa, b / sb, c * (sa * sb)


def cp_reconstruct(a, b, c):
    """Dense reconstruction sum_r a_r ∘ b_r ∘ c_r."""
    return jnp.einsum("ir,jr,kr->ijk", a, b, c)
