"""AOT lowering: JAX/Pallas ALS sweep → HLO text artifacts for the Rust
PJRT runtime.

Interchange format is HLO **text**, not serialized HloModuleProto: jax ≥ 0.5
emits protos with 64-bit instruction ids that xla_extension 0.5.1 (what the
published ``xla`` 0.1.6 crate binds) rejects; the text parser reassigns ids
and round-trips cleanly (see /opt/xla-example/README.md).

Usage::

    python -m compile.aot --out-dir ../artifacts

Emits one ``als_sweep_i{I}_j{J}_k{K}_r{R}.hlo.txt`` per shape-bank entry
plus a ``manifest.tsv`` the Rust artifact registry reads.
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile.model import als_sweep

# The shape bank: (I, J, K, R) executables compiled ahead of time. Samples
# are zero-padded up to the smallest covering entry (exactness argument in
# compile/model.py). Kept deliberately small — each entry is one PJRT
# compilation at Rust start-up.
SHAPE_BANK = [
    (16, 16, 16, 4),
    (32, 32, 32, 4),
    (32, 32, 32, 8),
    (64, 64, 64, 4),
    (64, 64, 64, 8),
    (96, 96, 96, 8),
]


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (return_tuple=True so the Rust
    side unwraps one tuple)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_entry(i, j, k, r) -> str:
    spec_x = jax.ShapeDtypeStruct((i, j, k), jnp.float32)
    spec_a = jax.ShapeDtypeStruct((i, r), jnp.float32)
    spec_b = jax.ShapeDtypeStruct((j, r), jnp.float32)
    spec_c = jax.ShapeDtypeStruct((k, r), jnp.float32)
    # keep_unused: the sweep overwrites `a` before reading it, so jit would
    # otherwise drop parameter 1 and break the Rust side's 4-buffer call.
    lowered = jax.jit(als_sweep, keep_unused=True).lower(spec_x, spec_a, spec_b, spec_c)
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--bank",
        default=None,
        help="comma-separated I:J:K:R entries overriding the default bank",
    )
    args = ap.parse_args()
    bank = SHAPE_BANK
    if args.bank:
        bank = []
        for entry in args.bank.split(","):
            i, j, k, r = (int(v) for v in entry.split(":"))
            bank.append((i, j, k, r))
    os.makedirs(args.out_dir, exist_ok=True)
    manifest = []
    for i, j, k, r in bank:
        name = f"als_sweep_i{i}_j{j}_k{k}_r{r}.hlo.txt"
        path = os.path.join(args.out_dir, name)
        text = lower_entry(i, j, k, r)
        with open(path, "w") as f:
            f.write(text)
        manifest.append((name, i, j, k, r))
        print(f"wrote {path} ({len(text)} chars)")
    with open(os.path.join(args.out_dir, "manifest.tsv"), "w") as f:
        f.write("# file\tI\tJ\tK\tR\n")
        for name, i, j, k, r in manifest:
            f.write(f"{name}\t{i}\t{j}\t{k}\t{r}\n")
    print(f"manifest: {len(manifest)} entries")


if __name__ == "__main__":
    main()
