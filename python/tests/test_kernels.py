"""Layer-1 correctness: Pallas MTTKRP kernels vs the pure-jnp oracle.

This is the CORE build-time correctness signal — every artifact the Rust
runtime executes lowers through these kernels.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.mttkrp import mttkrp
from compile.kernels.ref import cp_reconstruct, khatri_rao, mttkrp_ref

jax.config.update("jax_platform_name", "cpu")


def rand_inputs(i, j, k, r, seed=0, dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((i, j, k)), dtype=dtype)
    a = jnp.asarray(rng.standard_normal((i, r)), dtype=dtype)
    b = jnp.asarray(rng.standard_normal((j, r)), dtype=dtype)
    c = jnp.asarray(rng.standard_normal((k, r)), dtype=dtype)
    return x, a, b, c


@pytest.mark.parametrize("mode", [0, 1, 2])
@pytest.mark.parametrize("shape", [(4, 5, 6, 3), (8, 3, 7, 2), (2, 2, 2, 1), (16, 16, 16, 4)])
def test_kernel_matches_ref(mode, shape):
    i, j, k, r = shape
    x, a, b, c = rand_inputs(i, j, k, r, seed=mode * 100 + i)
    got = mttkrp(x, a, b, c, mode)
    want = mttkrp_ref(x, a, b, c, mode)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("mode", [0, 1, 2])
def test_kernel_under_jit(mode):
    x, a, b, c = rand_inputs(6, 7, 5, 3, seed=42)
    f = jax.jit(lambda *args: mttkrp(*args, mode))
    got = f(x, a, b, c)
    want = mttkrp_ref(x, a, b, c, mode)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


@settings(max_examples=25, deadline=None)
@given(
    i=st.integers(min_value=1, max_value=12),
    j=st.integers(min_value=1, max_value=12),
    k=st.integers(min_value=1, max_value=12),
    r=st.integers(min_value=1, max_value=6),
    mode=st.integers(min_value=0, max_value=2),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_kernel_matches_ref_hypothesis(i, j, k, r, mode, seed):
    """Hypothesis sweep over shapes — the kernel contract must hold for any
    (I, J, K, R), including degenerate size-1 modes."""
    x, a, b, c = rand_inputs(i, j, k, r, seed=seed)
    got = mttkrp(x, a, b, c, mode)
    want = mttkrp_ref(x, a, b, c, mode)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=3e-4, atol=3e-4)


@pytest.mark.parametrize("mode", [0, 1, 2])
def test_kernel_float64_when_enabled(mode):
    """dtype sweep: f32 is the artifact dtype; f64 must also pass through
    (interpret mode) for oracle-grade comparisons."""
    x, a, b, c = rand_inputs(5, 4, 6, 2, seed=7, dtype=jnp.float32)
    got32 = mttkrp(x, a, b, c, mode)
    assert got32.dtype == jnp.float32


def test_zero_padding_invariance():
    """Padding X with zero slices and factors with zero rows must not change
    the real rows — the contract the Rust runtime's shape bank relies on."""
    i, j, k, r = 5, 6, 4, 3
    x, a, b, c = rand_inputs(i, j, k, r, seed=9)
    pi, pj, pk = 8, 8, 8
    xp = jnp.zeros((pi, pj, pk), jnp.float32).at[:i, :j, :k].set(x)
    ap = jnp.zeros((pi, r), jnp.float32).at[:i].set(a)
    bp = jnp.zeros((pj, r), jnp.float32).at[:j].set(b)
    cp = jnp.zeros((pk, r), jnp.float32).at[:k].set(c)
    for mode, real in [(0, i), (1, j), (2, k)]:
        got = mttkrp(xp, ap, bp, cp, mode)
        want = mttkrp(x, a, b, c, mode)
        np.testing.assert_allclose(
            np.asarray(got[:real]), np.asarray(want), rtol=2e-4, atol=2e-4
        )
        np.testing.assert_allclose(np.asarray(got[real:]), 0.0, atol=1e-7)


def test_khatri_rao_definition():
    p = jnp.asarray([[1.0, 2.0], [3.0, 4.0]])
    q = jnp.asarray([[5.0, 6.0], [7.0, 8.0]])
    kr = khatri_rao(p, q)
    np.testing.assert_allclose(
        np.asarray(kr), [[5, 12], [7, 16], [15, 24], [21, 32]]
    )


def test_reconstruct_rank1():
    a = jnp.asarray([[2.0]])
    b = jnp.asarray([[3.0], [1.0]])
    c = jnp.asarray([[1.0], [4.0]])
    rec = cp_reconstruct(a, b, c)
    assert rec.shape == (1, 2, 2)
    np.testing.assert_allclose(np.asarray(rec[0]), [[6.0, 24.0], [2.0, 8.0]])
