"""AOT path: lowering the ALS sweep to HLO text must succeed for every
shape-bank entry and produce parseable modules with the expected signature.
"""

import os
import subprocess
import sys
import tempfile

import pytest

from compile.aot import lower_entry, SHAPE_BANK


def test_lower_smallest_entry_produces_hlo_text():
    i, j, k, r = SHAPE_BANK[0]
    text = lower_entry(i, j, k, r)
    assert "ENTRY" in text
    assert "HloModule" in text
    # Three outputs (a, b, c) as a tuple.
    assert "tuple" in text.lower()


def test_lowered_text_mentions_shapes():
    text = lower_entry(16, 16, 16, 4)
    assert "f32[16,16,16]" in text
    assert "f32[16,4]" in text


@pytest.mark.slow
def test_aot_main_writes_bank(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    out = subprocess.run(
        [
            sys.executable,
            "-m",
            "compile.aot",
            "--out-dir",
            str(tmp_path),
            "--bank",
            "8:8:8:2",
        ],
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        env=env,
        capture_output=True,
        text=True,
        timeout=600,
    )
    assert out.returncode == 0, out.stderr
    files = os.listdir(tmp_path)
    assert "als_sweep_i8_j8_k8_r2.hlo.txt" in files
    assert "manifest.tsv" in files
    manifest = (tmp_path / "manifest.tsv").read_text()
    assert "als_sweep_i8_j8_k8_r2.hlo.txt\t8\t8\t8\t2" in manifest
