"""Layer-2 correctness: the ALS sweep (model.py) against the reference
sweep, convergence behaviour, and the zero-padding contract the AOT shape
bank depends on."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import als_sweep_ref, cp_reconstruct
from compile.model import als_sweep, als_sweeps, cp_loss

jax.config.update("jax_platform_name", "cpu")


def low_rank_tensor(i, j, k, r, seed=0, noise=0.0):
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((i, r)).astype(np.float32)
    b = rng.standard_normal((j, r)).astype(np.float32)
    c = rng.standard_normal((k, r)).astype(np.float32)
    x = np.einsum("ir,jr,kr->ijk", a, b, c)
    if noise:
        x = x + noise * rng.standard_normal(x.shape).astype(np.float32)
    return jnp.asarray(x)


def rand_factors(i, j, k, r, seed=1):
    rng = np.random.default_rng(seed)
    return (
        jnp.asarray(rng.uniform(size=(i, r)), dtype=jnp.float32),
        jnp.asarray(rng.uniform(size=(j, r)), dtype=jnp.float32),
        jnp.asarray(rng.uniform(size=(k, r)), dtype=jnp.float32),
    )


def test_sweep_matches_reference_sweep():
    x = low_rank_tensor(6, 7, 5, 2, seed=3)
    a, b, c = rand_factors(6, 7, 5, 2, seed=4)
    ga, gb, gc = als_sweep(x, a, b, c)
    ra, rb, rc = als_sweep_ref(x, a, b, c)
    np.testing.assert_allclose(np.asarray(ga), np.asarray(ra), rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(gb), np.asarray(rb), rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(gc), np.asarray(rc), rtol=2e-3, atol=2e-3)


def test_sweeps_decrease_loss_monotonically():
    x = low_rank_tensor(8, 8, 8, 3, seed=5, noise=0.05)
    a, b, c = rand_factors(8, 8, 8, 3, seed=6)
    losses = [float(cp_loss(x, a, b, c))]
    for _ in range(8):
        a, b, c = als_sweep(x, a, b, c)
        losses.append(float(cp_loss(x, a, b, c)))
    for before, after in zip(losses, losses[1:]):
        assert after <= before * (1 + 1e-5), losses


def test_converges_to_exact_fit_on_low_rank():
    # Gaussian init: all-positive uniform inits can land ALS in a known slow
    # swamp on mixed-sign data (sign flips take hundreds of sweeps).
    rng = np.random.default_rng(8)
    x = low_rank_tensor(8, 8, 8, 2, seed=7)
    a = jnp.asarray(rng.standard_normal((8, 2)), dtype=jnp.float32)
    b = jnp.asarray(rng.standard_normal((8, 2)), dtype=jnp.float32)
    c = jnp.asarray(rng.standard_normal((8, 2)), dtype=jnp.float32)
    for _ in range(30):
        a, b, c = als_sweep(x, a, b, c)
    rel = float(jnp.sqrt(cp_loss(x, a, b, c)) / jnp.linalg.norm(x.ravel()))
    assert rel < 1e-2, rel


def test_als_sweeps_fori_matches_python_loop():
    x = low_rank_tensor(6, 6, 6, 2, seed=9)
    a0, b0, c0 = rand_factors(6, 6, 6, 2, seed=10)
    a, b, c = a0, b0, c0
    for _ in range(4):
        a, b, c = als_sweep(x, a, b, c)
    fa, fb, fc = als_sweeps(x, a0, b0, c0, 4)
    np.testing.assert_allclose(np.asarray(fa), np.asarray(a), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(fc), np.asarray(c), rtol=1e-4, atol=1e-4)


def test_zero_padding_exactness_full_sweep():
    """THE shape-bank contract: a sweep on the zero-padded problem must equal
    the sweep on the unpadded problem on real indices, and keep padding zero.
    Covers dim padding AND rank padding."""
    i, j, k, r = 6, 5, 4, 2
    x = low_rank_tensor(i, j, k, r, seed=11, noise=0.1)
    a, b, c = rand_factors(i, j, k, r, seed=12)
    pi, pj, pk, pr = 8, 8, 8, 4
    xp = jnp.zeros((pi, pj, pk), jnp.float32).at[:i, :j, :k].set(x)
    ap = jnp.zeros((pi, pr), jnp.float32).at[:i, :r].set(a)
    bp = jnp.zeros((pj, pr), jnp.float32).at[:j, :r].set(b)
    cp = jnp.zeros((pk, pr), jnp.float32).at[:k, :r].set(c)
    for _ in range(3):
        a, b, c = als_sweep(x, a, b, c)
        ap, bp, cp = als_sweep(xp, ap, bp, cp)
    np.testing.assert_allclose(np.asarray(ap[:i, :r]), np.asarray(a), rtol=5e-3, atol=5e-3)
    np.testing.assert_allclose(np.asarray(bp[:j, :r]), np.asarray(b), rtol=5e-3, atol=5e-3)
    np.testing.assert_allclose(np.asarray(cp[:k, :r]), np.asarray(c), rtol=5e-3, atol=5e-3)
    # Padded rows and rank columns stay (near-)zero.
    np.testing.assert_allclose(np.asarray(ap[i:]), 0.0, atol=1e-5)
    np.testing.assert_allclose(np.asarray(ap[:, r:]), 0.0, atol=1e-5)
    np.testing.assert_allclose(np.asarray(cp[k:]), 0.0, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(
    i=st.integers(min_value=2, max_value=10),
    r=st.integers(min_value=1, max_value=4),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_sweep_never_nan_hypothesis(i, r, seed):
    """Robustness sweep: the ridge must keep every system solvable, even for
    overfactored random data."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((i, i, i)), dtype=jnp.float32)
    a, b, c = rand_factors(i, i, i, r, seed=seed % 1000)
    for _ in range(3):
        a, b, c = als_sweep(x, a, b, c)
    assert np.isfinite(np.asarray(a)).all()
    assert np.isfinite(np.asarray(b)).all()
    assert np.isfinite(np.asarray(c)).all()


def test_reconstruction_shape():
    a, b, c = rand_factors(3, 4, 5, 2, seed=13)
    assert cp_reconstruct(a, b, c).shape == (3, 4, 5)
