//! Social-network stream, served: the paper's motivating scenario (§I) — a
//! (wall-owner × poster × day) interaction tensor growing one day at a
//! time — running through the serving-layer API. Days are submitted to a
//! [`DecompositionService`] stream (bounded queue, backpressure, a
//! `Ticket` per day), while an *analyst thread* hammers the stream's
//! wait-free [`StreamHandle`] the whole time: epoch reads, reconstructed
//! entries and `top_k` wall-recommendations, all mid-ingest, never
//! blocking the writer and never observing a half-merged model.
//!
//! ```bash
//! cargo run --release --example social_stream
//! ```
//!
//! Uses the Facebook-wall simulation (heavy-tailed user popularity, shallow
//! time mode — Table III's shape signature) and reports per-day ingest
//! latency, slice throughput and concurrent read throughput — the numbers
//! a production deployment cares about.

use sambaten::coordinator::SamBaTenConfig;
use sambaten::datagen::RealDatasetSim;
use sambaten::metrics::relative_error;
use sambaten::serve::DecompositionService;
use sambaten::streaming::{StreamPump, TensorReplay};
use sambaten::tensor::{Tensor3, TensorData};
use sambaten::util::Stopwatch;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

const STREAM: &str = "facebook-wall";

fn main() -> anyhow::Result<()> {
    let ds = RealDatasetSim::by_name("Facebook-wall").unwrap();
    // Scaled-down simulation: ~126×126 users, 8+ days, heavy-tailed.
    let (full, _truth) = ds.generate(0.002, 99);
    let (ni, nj, nk) = full.dims();
    println!(
        "simulated Facebook-wall: {ni}x{nj}x{nk}, {} nnz ({:.3}% dense)",
        full.nnz(),
        100.0 * full.nnz() as f64 / (ni * nj * nk) as f64
    );

    // First days are the pre-existing tensor; the rest arrives as a stream.
    let TensorData::Sparse(s) = &full else { unreachable!() };
    let (existing, rest) = s.split_mode3(2.max(nk / 8));
    let existing = TensorData::Sparse(existing);

    let cfg = SamBaTenConfig::builder(ds.rank, 2, 4, 11).build()?;
    let svc = DecompositionService::with_queue_cap(2);
    let handle = svc.register(STREAM, &existing, cfg)?;

    // Analyst thread: continuous queries against whatever epoch is
    // currently published, while days ingest concurrently.
    let stop = Arc::new(AtomicBool::new(false));
    let analyst = {
        let handle = handle.clone();
        let stop = stop.clone();
        std::thread::spawn(move || {
            let mut queries = 0u64;
            let mut last_epoch = 0u64;
            while !stop.load(Ordering::Relaxed) {
                let snap = handle.snapshot();
                assert!(snap.epoch >= last_epoch, "epoch must be monotone");
                last_epoch = snap.epoch;
                // A consistent read: C's row count always matches the
                // published slice count, even mid-ingest.
                assert_eq!(snap.model().factors[2].rows(), snap.dims.2);
                let _recs = snap.top_k(0, 0, 3); // "who posts on wall 0?"
                let _e = snap.entry(0, 0, 0);
                queries += 3;
            }
            (queries, last_epoch)
        })
    };

    // Stream day-by-day (batch = 1 slice) through the pump into the
    // service's bounded queue; tickets join per-day ingest latencies.
    let sw = Stopwatch::started();
    let pump = StreamPump::spawn(TensorReplay::new(TensorData::Sparse(rest)), 1, true, 2)?;
    let mut tickets = Vec::new();
    while let Some(batch) = pump.next_batch() {
        tickets.push(svc.ingest(STREAM, batch?)?);
    }
    // Label each line by the day its batch brought in (the existing slices
    // plus this batch's position) — the handle's dims would race ahead of
    // the log since the worker keeps ingesting while we join tickets.
    let mut latencies = Vec::new();
    let mut day = existing.dims().2;
    for t in tickets {
        let stats = t.wait()?;
        latencies.push(stats.seconds);
        day += stats.k_new;
        println!(
            "day {:>3}: ingest {:.3}s (summary {:?}, ranks {:?})",
            day, stats.seconds, stats.sample_dims[0], stats.ranks_used
        );
    }
    let wall = sw.elapsed_secs();
    stop.store(true, Ordering::Relaxed);
    let (queries, last_seen) = analyst.join().expect("analyst thread");

    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let p50 = latencies[latencies.len() / 2];
    let p99 = latencies[(latencies.len() * 99 / 100).min(latencies.len() - 1)];
    let total: f64 = latencies.iter().sum();
    let snap = handle.snapshot();
    println!("\n== serving report ==");
    println!("days ingested    : {}", latencies.len());
    println!("latency p50 / p99: {:.3}s / {:.3}s", p50, p99);
    println!("throughput       : {:.2} slices/s", latencies.len() as f64 / total);
    println!(
        "concurrent reads : {queries} queries during ingest ({:.0}/s), last epoch seen {last_seen}",
        queries as f64 / wall
    );
    println!(
        "final model      : epoch {}, rank {}, rel_err {:.4}",
        snap.epoch,
        snap.rank(),
        relative_error(&full, snap.model())
    );
    for st in svc.shutdown() {
        println!(
            "stream stats     : {} batches, {} slices, {} errors, {:.2}s ingest",
            st.batches, st.slices, st.errors, st.ingest_seconds
        );
    }
    // The service runs on the shared work-stealing scheduler by default:
    // this one stream used a key on a hardware-sized pool, and its
    // per-repetition sample-ALS fan-out rode the same pool.
    if let Some(ps) = svc.pool_stats() {
        println!(
            "scheduler        : {} workers, {} tasks ({} stolen, {} panics)",
            ps.workers, ps.tasks_executed, ps.steals, ps.panics
        );
    }
    Ok(())
}
