//! Social-network stream: the paper's motivating scenario (§I) — a
//! (wall-owner × poster × day) interaction tensor growing one day at a
//! time, served through the streaming layer with backpressure.
//!
//! ```bash
//! cargo run --release --example social_stream
//! ```
//!
//! Uses the Facebook-wall simulation (heavy-tailed user popularity, shallow
//! time mode — Table III's shape signature) and reports per-batch ingest
//! latency and slice throughput, the numbers a production deployment cares
//! about.

use sambaten::coordinator::{SamBaTen, SamBaTenConfig};
use sambaten::datagen::RealDatasetSim;
use sambaten::metrics::relative_error;
use sambaten::streaming::{StreamPump, TensorReplay};
use sambaten::tensor::{Tensor3, TensorData};

fn main() -> anyhow::Result<()> {
    let ds = RealDatasetSim::by_name("Facebook-wall").unwrap();
    // Scaled-down simulation: ~126×126 users, 8+ days, heavy-tailed.
    let (full, _truth) = ds.generate(0.002, 99);
    let (ni, nj, nk) = full.dims();
    println!(
        "simulated Facebook-wall: {ni}x{nj}x{nk}, {} nnz ({:.3}% dense)",
        full.nnz(),
        100.0 * full.nnz() as f64 / (ni * nj * nk) as f64
    );

    // First day is the pre-existing tensor; the rest arrives as a stream.
    let TensorData::Sparse(s) = &full else { unreachable!() };
    let (existing, rest) = s.split_mode3(2.max(nk / 8));
    let existing = TensorData::Sparse(existing);

    let cfg = SamBaTenConfig::new(ds.rank, 2, 4, 11);
    let mut engine = SamBaTen::init(&existing, cfg)?;

    // Stream day-by-day (batch = 1 slice) through the bounded pump.
    let pump = StreamPump::spawn(TensorReplay::new(TensorData::Sparse(rest)), 1, true, 2)?;
    let mut latencies = Vec::new();
    while let Some(batch) = pump.next_batch() {
        let stats = engine.ingest(&batch)?;
        latencies.push(stats.seconds);
        println!(
            "day {:>3}: ingest {:.3}s (summary {:?}, ranks {:?})",
            engine.model().factors[2].rows(),
            stats.seconds,
            stats.sample_dims[0],
            stats.ranks_used
        );
    }

    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let p50 = latencies[latencies.len() / 2];
    let p99 = latencies[(latencies.len() * 99 / 100).min(latencies.len() - 1)];
    let total: f64 = latencies.iter().sum();
    println!("\n== serving report ==");
    println!("days ingested    : {}", latencies.len());
    println!("latency p50 / p99: {:.3}s / {:.3}s", p50, p99);
    println!("throughput       : {:.2} slices/s", latencies.len() as f64 / total);
    println!(
        "final model      : rank {}, rel_err {:.4}",
        engine.model().rank(),
        relative_error(engine.tensor(), engine.model())
    );
    Ok(())
}
