//! Phase-level performance probe: where does an ingest spend its time?
//! (sample extraction / summary decomposition / matching / merge).
//! The §Perf iteration log in EXPERIMENTS.md is measured with this driver.
//!
//! ```bash
//! cargo run --release --example perfprobe
//! ```

use sambaten::coordinator::{SamBaTen, SamBaTenConfig};
use sambaten::datagen::SyntheticSpec;

fn main() {
    // Dense 64^3 — the regime where the paper's crossover appears.
    for (name, density) in [("dense64", 1.0), ("sparse64", 0.55)] {
        let spec = SyntheticSpec::cube(64, 4, density, 0.05, 17);
        let (existing, batches, _) = spec.generate_stream(0.1, 12);
        let cfg = SamBaTenConfig::builder(4, 2, 4, 7).build().unwrap();
        let mut e = SamBaTen::init(&existing, cfg).unwrap();
        let (mut ts, mut td, mut tm, mut tg, mut tot) = (0.0, 0.0, 0.0, 0.0, 0.0);
        for b in &batches {
            let st = e.ingest(b).unwrap();
            ts += st.phase_sample_s;
            td += st.phase_decompose_s;
            tm += st.phase_match_s;
            tg += st.phase_merge_s;
            tot += st.seconds;
        }
        println!(
            "{name}: total {tot:.3}s  sample {ts:.3} decompose {td:.3} match {tm:.3} merge {tg:.3}"
        );
    }
}
