//! Location-based recommendation (§II-B, Figure 3a): a
//! (location × hot-spot × people) tensor whose updates are sometimes
//! *rank-deficient* — e.g. a quiet week in which only one latent travel
//! pattern is active. Demonstrates GETRANK quality control (§III-B):
//! without it, matching degrades on deficient batches; with it, the engine
//! estimates each summary's true rank and matches only those components.
//!
//! ```bash
//! cargo run --release --example recommender
//! ```

use sambaten::coordinator::{SamBaTen, SamBaTenConfig};
use sambaten::cp::CpModel;
use sambaten::datagen::SyntheticSpec;
use sambaten::linalg::Matrix;
use sambaten::metrics::{fms, relative_error};
use sambaten::tensor::{DenseTensor, TensorData};
use sambaten::util::Rng;

/// Build a stream whose later batches only contain 2 of the 4 latent
/// patterns (rank-deficient updates).
fn build_workload() -> (TensorData, Vec<TensorData>, TensorData, CpModel) {
    let dim = 24;
    let rank = 4;
    let spec = SyntheticSpec::cube(dim, rank, 1.0, 0.02, 7);
    let (full, truth) = spec.generate();
    let full = full.to_dense();
    // Re-synthesise the last 60% of the weeks from components {0, 1} only.
    let deficient = truth.select_components(&[0, 1]);
    let deficient_dense = deficient.to_dense();
    let k0 = (dim as f64 * 0.4) as usize;
    let mut mixed = full.clone();
    let mut rng = Rng::new(13);
    for k in k0..dim {
        for j in 0..dim {
            for i in 0..dim {
                mixed.set(i, j, k, deficient_dense.get(i, j, k) + 0.02 * rng.gaussian());
            }
        }
    }
    let (existing, rest) = mixed.split_mode3(k0);
    let mut batches = Vec::new();
    let mut rest = rest;
    while rest.dims().2 > 0 {
        let take = 4usize.min(rest.dims().2);
        let (head, tail) = rest.split_mode3(take);
        batches.push(TensorData::Dense(head));
        rest = tail;
    }
    let mut acc: TensorData = existing.clone().into();
    for b in &batches {
        acc.append_mode3(b);
    }
    (existing.into(), batches, acc, truth)
}

use sambaten::tensor::Tensor3;

fn run(quality_control: bool) -> anyhow::Result<(f64, f64, f64)> {
    let (existing, batches, full, truth) = build_workload();
    let cfg = SamBaTenConfig::new(4, 2, 4, 21).with_quality_control(quality_control);
    let mut engine = SamBaTen::init(&existing, cfg)?;
    let sw = sambaten::util::Stopwatch::started();
    for b in &batches {
        let stats = engine.ingest(b)?;
        if quality_control {
            println!("  batch ranks under GETRANK: {:?}", stats.ranks_used);
        }
    }
    let secs = sw.elapsed_secs();
    Ok((fms(engine.model(), &truth), relative_error(&full, engine.model()), secs))
}

fn main() -> anyhow::Result<()> {
    // Silence an unused-import lint path for Matrix in docs.
    let _ = Matrix::zeros(1, 1);
    let _ = DenseTensor::zeros(1, 1, 1);

    println!("recommender workload: 24x24x24, rank-4 truth, rank-2 deficient updates\n");
    println!("without GETRANK:");
    let (fms_off, err_off, t_off) = run(false)?;
    println!("  FMS {:.3}  rel_err {:.3}  ({:.2}s)\n", fms_off, err_off, t_off);
    println!("with GETRANK (quality control):");
    let (fms_on, err_on, t_on) = run(true)?;
    println!("  FMS {:.3}  rel_err {:.3}  ({:.2}s)", fms_on, err_on, t_on);
    println!(
        "\nGETRANK overhead {:.1}% — FMS {:+.3}, rel_err {:+.3}",
        100.0 * (t_on - t_off) / t_off,
        fms_on - fms_off,
        err_on - err_off
    );
    Ok(())
}
