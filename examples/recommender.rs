//! Location-based recommendation (§II-B, Figure 3a), served: a
//! (location × hot-spot × people) tensor whose updates are sometimes
//! *rank-deficient* — e.g. a quiet week in which only one latent travel
//! pattern is active. The workload runs through the serving-layer API: a
//! [`DecompositionService`] stream ingests weekly batches while a reader
//! thread polls the wait-free [`StreamHandle`] mid-ingest, and the final
//! recommendations come from `top_k` on a published snapshot.
//!
//! Demonstrates GETRANK quality control (§III-B): without it, matching
//! degrades on deficient batches; with it, the engine estimates each
//! summary's true rank and matches only those components.
//!
//! ```bash
//! cargo run --release --example recommender
//! ```

use sambaten::coordinator::SamBaTenConfig;
use sambaten::cp::CpModel;
use sambaten::datagen::SyntheticSpec;
use sambaten::metrics::{fms, relative_error};
use sambaten::serve::DecompositionService;
use sambaten::tensor::{Tensor3, TensorData};
use sambaten::util::Rng;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

/// Build a stream whose later batches only contain 2 of the 4 latent
/// patterns (rank-deficient updates).
fn build_workload() -> (TensorData, Vec<TensorData>, TensorData, CpModel) {
    let dim = 24;
    let rank = 4;
    let spec = SyntheticSpec::cube(dim, rank, 1.0, 0.02, 7);
    let (full, truth) = spec.generate();
    let full = full.to_dense();
    // Re-synthesise the last 60% of the weeks from components {0, 1} only.
    let deficient = truth.select_components(&[0, 1]);
    let deficient_dense = deficient.to_dense();
    let k0 = (dim as f64 * 0.4) as usize;
    let mut mixed = full.clone();
    let mut rng = Rng::new(13);
    for k in k0..dim {
        for j in 0..dim {
            for i in 0..dim {
                mixed.set(i, j, k, deficient_dense.get(i, j, k) + 0.02 * rng.gaussian());
            }
        }
    }
    let (existing, rest) = mixed.split_mode3(k0);
    let mut batches = Vec::new();
    let mut rest = rest;
    while rest.dims().2 > 0 {
        let take = 4usize.min(rest.dims().2);
        let (head, tail) = rest.split_mode3(take);
        batches.push(TensorData::Dense(head));
        rest = tail;
    }
    let mut acc: TensorData = existing.clone().into();
    for b in &batches {
        acc.append_mode3(b);
    }
    (existing.into(), batches, acc, truth)
}

fn run(quality_control: bool) -> anyhow::Result<(f64, f64, f64)> {
    let (existing, batches, full, truth) = build_workload();
    let cfg = SamBaTenConfig::builder(4, 2, 4, 21).quality_control(quality_control).build()?;
    let svc = DecompositionService::new();
    let handle = svc.register("recommender", &existing, cfg)?;

    // Reader polling the handle while the worker ingests: the epoch only
    // moves forward and every observed snapshot is internally consistent.
    let stop = Arc::new(AtomicBool::new(false));
    let reader = {
        let handle = handle.clone();
        let stop = stop.clone();
        std::thread::spawn(move || {
            let mut last = 0u64;
            let mut reads = 0u64;
            while !stop.load(Ordering::Relaxed) {
                let snap = handle.snapshot();
                assert!(snap.epoch >= last);
                last = snap.epoch;
                assert_eq!(snap.model().factors[2].rows(), snap.dims.2);
                reads += 1;
            }
            reads
        })
    };

    let sw = sambaten::util::Stopwatch::started();
    let tickets: Vec<_> = batches
        .into_iter()
        .map(|b| svc.ingest("recommender", b))
        .collect::<anyhow::Result<_>>()?;
    for t in tickets {
        let stats = t.wait()?;
        if quality_control {
            println!("  batch ranks under GETRANK: {:?}", stats.ranks_used);
        }
    }
    let secs = sw.elapsed_secs();
    stop.store(true, Ordering::Relaxed);
    let reads = reader.join().expect("reader thread");

    let snap = handle.snapshot();
    println!("  ({reads} wait-free reads during {:.2}s of ingest)", secs);
    // Final serving query: hot-spots recommended for location 0, scored
    // over the whole people mode.
    let recs = snap.top_k(0, 0, 3);
    let ids: Vec<usize> = recs.iter().map(|(j, _)| *j).collect();
    println!("  top hot-spots for location 0: {ids:?}");
    let result = (fms(snap.model(), &truth), relative_error(&full, snap.model()), secs);
    svc.shutdown();
    Ok(result)
}

fn main() -> anyhow::Result<()> {
    println!("recommender workload: 24x24x24, rank-4 truth, rank-2 deficient updates\n");
    println!("without GETRANK:");
    let (fms_off, err_off, t_off) = run(false)?;
    println!("  FMS {:.3}  rel_err {:.3}  ({:.2}s)\n", fms_off, err_off, t_off);
    println!("with GETRANK (quality control):");
    let (fms_on, err_on, t_on) = run(true)?;
    println!("  FMS {:.3}  rel_err {:.3}  ({:.2}s)", fms_on, err_on, t_on);
    println!(
        "\nGETRANK overhead {:.1}% — FMS {:+.3}, rel_err {:+.3}",
        100.0 * (t_on - t_off) / t_off,
        fms_on - fms_off,
        err_on - err_off
    );
    Ok(())
}
