//! End-to-end validation driver (the repository's acceptance run):
//! exercises ALL layers on a real small workload and reports the paper's
//! headline metric.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_eval
//! ```
//!
//! Pipeline: synthetic + simulated-real streams → SamBaTen (native AND, if
//! the artifact bank is built, the AOT JAX/Pallas PJRT engine) vs all four
//! baselines → headline: SamBaTen's speedup over the recompute baseline at
//! comparable accuracy (paper: 25-30× vs OnlineCP on NIPS; "comparable
//! accuracy" Tables IV-V). Results land in results/e2e.csv and
//! EXPERIMENTS.md records a reference run.

use sambaten::coordinator::{SamBaTen, SamBaTenConfig};
use sambaten::datagen::{RealDatasetSim, SyntheticSpec};
use sambaten::eval::runner::{run_stream, MethodKind, Workload};
use sambaten::io::csv::{num, CsvWriter};
use sambaten::runtime::{artifacts_available, artifacts_dir, PjrtAlsSolver, PjrtService};
use sambaten::tensor::Tensor3;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let mut csv = CsvWriter::create(
        std::path::Path::new("results/e2e.csv"),
        &["workload", "method", "seconds", "rel_err", "fitness_vs_cpals", "completed"],
    )?;

    // ---- workload 1: dense synthetic cube (Table IV regime).
    let dense = {
        let spec = SyntheticSpec::cube(40, 4, 1.0, 0.05, 17);
        let (existing, batches, truth) = spec.generate_stream(0.1, 10);
        let (full, _) = spec.generate();
        ("dense-40", Workload { existing, batches, full, truth: Some(truth), rank: 4 })
    };
    // ---- workload 2: sparse synthetic (Table V regime).
    let sparse = {
        let spec = SyntheticSpec::cube(40, 4, 0.55, 0.05, 19);
        let (existing, batches, truth) = spec.generate_stream(0.1, 10);
        let (full, _) = spec.generate();
        ("sparse-40", Workload { existing, batches, full, truth: Some(truth), rank: 4 })
    };
    // ---- workload 3: simulated NIPS (Table VI regime).
    let nips = {
        let ds = RealDatasetSim::by_name("NIPS").unwrap();
        let (existing, batches, truth) = ds.generate_stream(0.010, 23);
        let mut full = existing.clone();
        for b in &batches {
            full.append_mode3(b);
        }
        ("NIPS-sim", Workload { existing, batches, full, truth: Some(truth), rank: ds.rank })
    };

    let mut headline: Vec<String> = Vec::new();
    for (name, w) in [dense, sparse, nips] {
        println!("\n=== workload {name}: {:?}, {} batches ===", w.full.dims(), w.batches.len());
        let cfg = SamBaTenConfig::builder(w.rank, 2, 4, 7).build()?;
        let outcomes = run_stream(&w, &MethodKind::ALL, &cfg, 120.0)?;
        let mut cpals_time = f64::NAN;
        let mut samba_time = f64::NAN;
        let mut samba_err = f64::NAN;
        let mut cpals_err = f64::NAN;
        for o in &outcomes {
            println!(
                "  {:>9}: {:>9} s  rel_err {}",
                o.method,
                if o.completed { format!("{:.3}", o.seconds) } else { "N/A".into() },
                if o.completed { format!("{:.4}", o.rel_err) } else { "N/A".into() }
            );
            csv.row(&[
                name.into(),
                o.method.into(),
                num(o.seconds),
                num(o.rel_err),
                o.fitness_vs_cpals.map(num).unwrap_or_default(),
                o.completed.to_string(),
            ])?;
            match o.method {
                "CP_ALS" if o.completed => {
                    cpals_time = o.seconds;
                    cpals_err = o.rel_err;
                }
                "SamBaTen" if o.completed => {
                    samba_time = o.seconds;
                    samba_err = o.rel_err;
                }
                _ => {}
            }
        }
        if cpals_time.is_finite() && samba_time.is_finite() {
            headline.push(format!(
                "{name}: SamBaTen {:.1}x faster than CP_ALS recompute (err {:.3} vs {:.3})",
                cpals_time / samba_time,
                samba_err,
                cpals_err
            ));
        }
    }

    // ---- PJRT three-layer check: run the dense workload again with the
    // AOT JAX/Pallas engine if the artifact bank exists.
    if artifacts_available() {
        println!("\n=== three-layer check (PJRT AOT engine) ===");
        let spec = SyntheticSpec::cube(30, 4, 1.0, 0.05, 29);
        let (existing, batches, _) = spec.generate_stream(0.1, 8);
        let (full, _) = spec.generate();
        let svc = PjrtService::start(artifacts_dir())?;
        let cfg = SamBaTenConfig::builder(4, 2, 4, 7)
            .solver(Arc::new(PjrtAlsSolver::new(svc.clone())))
            .build()?;
        let mut engine = SamBaTen::init(&existing, cfg)?;
        let sw = sambaten::util::Stopwatch::started();
        for b in &batches {
            engine.ingest(b)?;
        }
        let err = sambaten::metrics::relative_error(&full, engine.model());
        println!(
            "  pjrt-als engine: {:.2}s, rel_err {:.4} ({} PJRT jobs, {} bank misses)",
            sw.elapsed_secs(),
            err,
            svc.job_count(),
            svc.fallback_count()
        );
        headline.push(format!(
            "three-layer (Rust→PJRT→JAX/Pallas AOT): rel_err {err:.3} over {} jobs",
            svc.job_count()
        ));
        anyhow::ensure!(err < 0.5, "PJRT path accuracy regressed: {err}");
    } else {
        println!("\n(artifact bank missing — run `make artifacts` for the PJRT check)");
    }

    csv.flush()?;
    println!("\n== HEADLINE ==");
    for h in &headline {
        println!("  {h}");
    }
    println!("csv: results/e2e.csv");
    Ok(())
}
