//! Cluster demo: shard a fleet of streams by consistent hashing and
//! replicate every batch's snapshot through the binary wire codec.
//!
//! ```bash
//! cargo run --release --example cluster_demo
//! ```
//!
//! Builds a 3-shard × 2-replica [`ClusterService`], registers six
//! streams (the ring decides which shard each lands on), drives them
//! concurrently, and then proves the replication contract the hard way:
//! replica reads are compared to the primary's **bit for bit** — not
//! approximately, `to_bits()`-equal — because snapshot frames carry the
//! primary's copy-on-write block state (base payloads + read scales),
//! never re-derived matrices. Steady-state frames are deltas:
//! `O(rows_touched · R)` on the wire regardless of accumulated size.
//!
//! The same frames travel over TCP: run `sambaten cluster --listen
//! 127.0.0.1:7171` in one terminal and `sambaten cluster --join
//! 127.0.0.1:7171` in another for the two-process version.

use sambaten::cluster::{ClusterConfig, ClusterService};
use sambaten::coordinator::SamBaTenConfig;
use sambaten::datagen::SyntheticSpec;

fn main() -> anyhow::Result<()> {
    let cluster = ClusterService::new(ClusterConfig::new(3).replicas(2))?;
    println!("cluster: 3 shards × 2 replicas\n");

    // Register six streams; placement is a pure hash-ring lookup.
    let streams = 6usize;
    let mut batch_sets = Vec::new();
    for s in 0..streams {
        let name = format!("sensor-{s}");
        let spec = SyntheticSpec::dense(40, 32, 30, 3, 0.05, 100 + s as u64);
        let (existing, batches, _) = spec.generate_stream(0.3, 3);
        let cfg = SamBaTenConfig::builder(3, 2, 2, 7).build()?;
        cluster.register(&name, &existing, cfg)?;
        println!("registered {name} -> shard {}", cluster.shard_of(&name));
        batch_sets.push((name, batches));
    }

    // Drive all streams: submit a round of batches, then wait the round
    // of tickets. A resolved ticket means the batch is merged on the
    // primary AND applied to every replica.
    let rounds = batch_sets.iter().map(|(_, b)| b.len()).max().unwrap_or(0);
    for round in 0..rounds {
        let mut tickets = Vec::new();
        for (name, batches) in &batch_sets {
            if let Some(batch) = batches.get(round) {
                tickets.push((name.clone(), cluster.ingest(name, batch.clone())?));
            }
        }
        for (name, ticket) in tickets {
            let stats = ticket.wait()?;
            println!("  round {round}: {name} +{} slices in {:.3}s", stats.k_new, stats.seconds);
        }
    }

    // The proof: replica reads are the primary's reads, bit for bit.
    println!("\n== replication report ==");
    for name in cluster.stream_names() {
        let cs = cluster.cluster_stats(&name)?;
        let primary = cluster.handle(&name)?.snapshot();
        for idx in 0..2 {
            let replica = cluster.replica_handle(&name, idx)?.snapshot();
            assert_eq!(primary.epoch, replica.epoch);
            for row in [0, primary.dims.0 / 2] {
                let p = primary.top_k(0, row, 3);
                let r = replica.top_k(0, row, 3);
                assert_eq!(p.len(), r.len());
                for (a, b) in p.iter().zip(&r) {
                    assert_eq!(a.0, b.0);
                    assert_eq!(a.1.to_bits(), b.1.to_bits(), "replica bits diverged");
                }
            }
        }
        println!(
            "  {name}: shard {}  epoch {}  frames {} delta / {} full  {} bytes",
            cs.shard, cs.primary.epoch, cs.frames_delta, cs.frames_full, cs.bytes_replicated
        );
    }
    cluster.shutdown();
    println!("\nok: every replica served the primary's bits at every checked read");
    Ok(())
}
