//! Quickstart: decompose a growing tensor incrementally with SamBaTen.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Generates a rank-4 synthetic tensor, treats 20% of it as the
//! pre-existing data, streams the rest in batches, and compares the
//! incrementally-maintained model against a full CP-ALS recompute.
//!
//! API tour: configs come from the validating
//! [`SamBaTenConfig::builder`]; `ingest` is the write path; and the
//! engine's [`handle()`](SamBaTen::handle) exposes the wait-free read path
//! — epoch-stamped snapshots with `entry` / `fit` / `top_k` queries that
//! other threads may hit while `ingest` runs (see the `social_stream`
//! example and the `serve` CLI command for the full multi-stream service).

use sambaten::coordinator::{SamBaTen, SamBaTenConfig};
use sambaten::cp::{cp_als, AlsOptions};
use sambaten::datagen::SyntheticSpec;
use sambaten::metrics::{relative_error, relative_fitness};
use sambaten::util::timer::timed;

fn main() -> anyhow::Result<()> {
    // A 48×48×60 dense tensor built from 4 known components + 5% noise.
    let spec = SyntheticSpec::dense(48, 48, 60, 4, 0.05, 42);
    let (existing, batches, _truth) = spec.generate_stream(0.2, 10);
    let (full, _) = spec.generate();

    // rank 4, sampling factor s=2, r=4 repetitions — validated at build().
    let cfg = SamBaTenConfig::builder(4, 2, 4, 7).build()?;
    let mut engine = SamBaTen::init(&existing, cfg)?;
    // The wait-free read handle; cloneable into as many readers as needed.
    let handle = engine.handle();
    println!("initial fit on existing slices: {:.4}", handle.fit(&existing));

    let (_, incr_secs) = timed(|| -> anyhow::Result<()> {
        for (n, batch) in batches.iter().enumerate() {
            let stats = engine.ingest(batch)?;
            println!(
                "batch {:>2}: +{} slices in {:.3}s (summary {:?})",
                n + 1,
                stats.k_new,
                stats.seconds,
                stats.sample_dims[0]
            );
        }
        Ok(())
    });

    // Reference: recompute CP-ALS on the final tensor from scratch.
    let (reference, full_secs) = timed(|| {
        cp_als(&full, 4, &AlsOptions { seed: 1, ..Default::default() }).unwrap().0
    });

    // Read through the published snapshot — the same view any concurrent
    // reader would see, stamped with the number of ingests applied.
    let snap = handle.snapshot();
    let model = snap.model();
    println!("\n== results (snapshot epoch {}) ==", snap.epoch);
    println!("SamBaTen total ingest time : {incr_secs:.2}s");
    println!("full CP-ALS recompute time : {full_secs:.2}s (one final decomposition)");
    println!("SamBaTen relative error    : {:.4}", relative_error(&full, model));
    println!("CP-ALS   relative error    : {:.4}", relative_error(&full, &reference));
    println!(
        "relative fitness (SamBaTen vs CP-ALS): {:.4}",
        relative_fitness(&full, model, &reference)
    );
    Ok(())
}
